//! Immutable, shareable read-path view of a trained [`Figmn`].
//!
//! The learner's entire mutable state is the flat component arenas of
//! its [`ComponentStore`], so publishing a snapshot is a bulk copy of
//! five contiguous buffers (`store.clone()`) — no per-component
//! traversal, no pointer chasing. Scorer threads serve
//! `score`/`predict` traffic from the latest snapshot without taking
//! any lock the learner holds — the coordinator's read–write split (see
//! `crate::coordinator`).
//!
//! ## Equivalence guarantee
//!
//! Every scoring method here runs the *same instruction sequence* as the
//! serial path of [`Figmn`] (`log_density`, `predict`, `posteriors`,
//! `score_batch`, `predict_batch`), sharing the same helpers
//! (`log_gaussian`, `softmax_posteriors`, `logsumexp_tree`,
//! `precision_conditional`) over the same packed arenas. A snapshot
//! taken after N learn steps therefore returns **bit-identical**
//! results to calling the serial model trained on the same N-point
//! prefix — enforced by this module's tests and the
//! `serving_read_path` bench.
//!
//! In [`super::ReplicaMode::F32`] the density/posterior surfaces serve
//! from an f32 [`ReplicaStore`] materialized at construction — half the
//! bytes per sweep, tolerance-equivalent (not bitwise) to the f64 path
//! within the configured tolerance (see [`super::replica`]).
//! Conditional inference always stays f64, and with `ReplicaMode::Off`
//! (the default) every surface remains byte-identical to the
//! pre-replica read path.
//!
//! In [`SearchMode::TopC`] the density/posterior surfaces instead walk
//! a [`CandidateIndex`] **frozen at publish**: rebuilt deterministically
//! from the copied arenas at construction and never mutated, so every
//! scorer thread sees one immutable candidate partition and repeated
//! queries are bit-identical to each other. Candidate terms are exact;
//! only the non-candidate tail is dropped (the [`SearchMode`] tolerance
//! contract). Conditional inference (`predict*`, `class_scores*`)
//! always evaluates every component.
//!
//! ## Batch surfaces are query-blocked
//!
//! The `*_batch` methods run **component-outer / query-inner** over
//! blocks of [`SCORE_BLOCK`] queries (see [`super::score_block`]): each
//! packed component row is streamed once per block through the
//! multi-query kernels instead of once per query, which is what makes
//! the snapshot read path bandwidth-efficient at large `D`. Blocking
//! never changes a query's floating-point sequence, so every batch
//! method stays bit-identical to mapping its per-point counterpart —
//! in both kernel modes (`tests/blocked_scoring_equivalence.rs`).
//!
//! [`Figmn`]: super::Figmn
//! [`ComponentStore`]: super::ComponentStore

use super::candidates::{CandidateIndex, SearchMode};
use super::inference::{
    precision_conditional, precision_conditional_multi_with, target_block_cholesky,
};
use super::replica::{ReplicaBlock, ReplicaStore};
use super::score_block::{ScoreBlock, SCORE_BLOCK};
use super::store::ComponentStore;
use super::supervised::clip_normalize;
use super::{index_split, log_gaussian, softmax_posteriors, GmmConfig};
use crate::engine::logsumexp_tree;
use crate::linalg::{packed, sub_into, Cholesky, KernelMode};

/// An immutable copy of a [`super::Figmn`]'s mixture state, safe to
/// share across scorer threads (`Send + Sync`, plain data only).
#[derive(Debug, Clone)]
pub struct ModelSnapshot {
    cfg: GmmConfig,
    store: ComponentStore,
    /// Σ sp, precomputed with the same left-fold the live model uses so
    /// priors come out bit-identical.
    total_sp: f64,
    /// Learn steps the source model had seen when this snapshot was
    /// taken — the snapshot's version for staleness accounting.
    points: u64,
    /// Supervised split: leading `n_features` dims are features. Equals
    /// `dim` (with `n_classes == 0`) for a plain joint-density model.
    n_features: usize,
    n_classes: usize,
    /// Index split for the class-scores conditionals, precomputed once
    /// at construction so `class_scores`/`class_scores_batch` don't
    /// rebuild two Vecs per call on the serving hot path.
    feature_idx: Vec<usize>,
    class_idx: Vec<usize>,
    /// Candidate index for [`SearchMode::TopC`] serving, rebuilt
    /// deterministically from the frozen arenas at construction and
    /// never mutated again — the read path's "index frozen at publish".
    /// `None` in strict mode (and on an empty store), where every
    /// surface runs the exact full-K sweep.
    index: Option<CandidateIndex>,
    /// Per-component target-block Cholesky factors (`W = Λ_tt`) for the
    /// recorded class split, hoisted out of the per-(component, block)
    /// inner loop of the serving conditional path. Empty when the
    /// snapshot has no class split.
    split_factors: Vec<Cholesky>,
    /// f32 copy of the mean/matrix arenas, materialized at construction
    /// when `cfg.replica_mode` is [`super::ReplicaMode::F32`] — the
    /// density surfaces then stream half the bytes per sweep, within
    /// the configured tolerance of the f64 path (see [`super::replica`]
    /// for the contract). `None` (the default) keeps every surface
    /// byte-identical to the pre-replica read path. A frozen top-C
    /// index takes precedence where both are configured.
    replica: Option<ReplicaStore>,
}

impl ModelSnapshot {
    pub(crate) fn new(
        cfg: GmmConfig,
        store: ComponentStore,
        points: u64,
        n_features: usize,
        n_classes: usize,
    ) -> ModelSnapshot {
        let total_sp = store.total_sp();
        let (feature_idx, class_idx) = index_split(n_features, n_classes);
        let index = match cfg.search_mode {
            SearchMode::TopC { .. } if !store.is_empty() => Some(CandidateIndex::build(&store)),
            _ => None,
        };
        let split_factors = split_factors(&store, cfg.dim, &class_idx);
        let replica = (cfg.replica_mode.is_on() && !store.is_empty())
            .then(|| ReplicaStore::from_store(&store));
        ModelSnapshot {
            cfg,
            store,
            total_sp,
            points,
            n_features,
            n_classes,
            feature_idx,
            class_idx,
            index,
            split_factors,
            replica,
        }
    }

    /// Record the supervised feature/class split (for
    /// [`ModelSnapshot::class_scores`]). The blocks must tile the joint
    /// dimension.
    pub fn with_split(mut self, n_features: usize, n_classes: usize) -> ModelSnapshot {
        assert_eq!(
            n_features + n_classes,
            self.cfg.dim,
            "split must tile the joint dimension"
        );
        self.n_features = n_features;
        self.n_classes = n_classes;
        let (feature_idx, class_idx) = index_split(n_features, n_classes);
        self.feature_idx = feature_idx;
        self.class_idx = class_idx;
        self.split_factors = split_factors(&self.store, self.cfg.dim, &self.class_idx);
        self
    }

    pub fn num_components(&self) -> usize {
        self.store.len()
    }

    pub fn dim(&self) -> usize {
        self.cfg.dim
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Learn steps the source model had seen at publish time.
    pub fn points_seen(&self) -> u64 {
        self.points
    }

    /// Arena payload bytes this snapshot holds (same accounting as the
    /// source model's `model_bytes`).
    pub fn model_bytes(&self) -> usize {
        self.store.model_bytes()
    }

    /// Whether this snapshot carries an f32 read replica.
    pub fn has_replica(&self) -> bool {
        self.replica.is_some()
    }

    /// f32 replica payload bytes (0 when [`ReplicaMode::Off`]) — the
    /// extra memory the replica tier trades for halved read bandwidth.
    ///
    /// [`ReplicaMode::Off`]: super::ReplicaMode::Off
    pub fn replica_bytes(&self) -> usize {
        self.replica.as_ref().map_or(0, ReplicaStore::replica_bytes)
    }

    /// How many learn steps a model that has now seen `current_points`
    /// is ahead of this snapshot (the read path's staleness).
    pub fn staleness(&self, current_points: u64) -> u64 {
        current_points.saturating_sub(self.points)
    }

    /// The top-C candidate list and exact `ln p(x|j)` terms for one
    /// query against the frozen index — the same per-candidate
    /// instruction sequence as `Figmn::topc_loglik`, so a snapshot and
    /// a fresh-indexed model agree bit-for-bit on the same arenas.
    fn topc_loglik(&self, index: &CandidateIndex, x: &[f64], c: usize) -> (Vec<u32>, Vec<f64>) {
        let d = self.cfg.dim;
        let mode = self.cfg.kernel_mode;
        let mut cands = Vec::new();
        index.query(x, c, &self.store, &mut cands);
        let mut e = vec![0.0; d];
        let mut tmp = vec![0.0; if mode == KernelMode::Fast { d } else { 0 }];
        let ll = cands
            .iter()
            .map(|&j| {
                let j = j as usize;
                sub_into(x, self.store.mean(j), &mut e);
                log_gaussian(
                    packed::quad_form_scratch(self.store.mat(j), d, &e, &mut tmp, mode),
                    self.store.log_det(j),
                    d,
                )
            })
            .collect();
        (cands, ll)
    }

    /// The `(index, C)` pair when this snapshot serves top-C traffic.
    fn active_index(&self) -> Option<(&CandidateIndex, usize)> {
        let c = self.cfg.search_mode.top_c()?;
        self.index.as_ref().map(|idx| (idx, c))
    }

    /// Joint log-density `ln p(x)` — bit-identical to
    /// [`super::IncrementalMixture::log_density`] on the source model
    /// in strict search mode (the snapshot runs the same kernels in the
    /// same `cfg.kernel_mode` the source model was configured with). In
    /// [`SearchMode::TopC`] the snapshot evaluates its own frozen
    /// candidate index — deterministic and exact per candidate, but the
    /// candidate *set* is rebuilt from the published arenas, so values
    /// are tolerance-equivalent (not bitwise) to a live model whose
    /// index has accumulated drift bookkeeping.
    pub fn log_density(&self, x: &[f64]) -> f64 {
        assert!(!self.store.is_empty(), "log_density on empty snapshot");
        assert_eq!(x.len(), self.cfg.dim, "log_density: dimensionality mismatch");
        if let Some((index, c)) = self.active_index() {
            let (cands, ll) = self.topc_loglik(index, x, c);
            let terms: Vec<f64> = cands
                .iter()
                .zip(ll.iter())
                .map(|(&j, &llj)| llj + (self.store.sp(j as usize) / self.total_sp).ln())
                .collect();
            return logsumexp_tree(&terms);
        }
        if let Some(rep) = &self.replica {
            // Replica tier: the same sweep over the f32 arenas —
            // tolerance-equivalent to the f64 path below, half the
            // bytes streamed (see `super::replica`).
            let mut blk = ReplicaBlock::new(self.cfg.dim, 1);
            blk.load_query(x);
            let mut terms = Vec::with_capacity(self.store.len());
            for j in 0..self.store.len() {
                let offset = (self.store.sp(j) / self.total_sp).ln();
                terms.push(blk.component_terms(rep, j, self.store.log_det(j), 1, offset)[0]);
            }
            return logsumexp_tree(&terms);
        }
        let d = self.cfg.dim;
        let mode = self.cfg.kernel_mode;
        let mut e = vec![0.0; d];
        // Kernel scratch is only read by the fast path; don't pay the
        // allocation on the (default) strict read path.
        let mut tmp = vec![0.0; if mode == KernelMode::Fast { d } else { 0 }];
        let mut terms = Vec::with_capacity(self.store.len());
        for j in 0..self.store.len() {
            sub_into(x, self.store.mean(j), &mut e);
            let ll = log_gaussian(
                packed::quad_form_scratch(self.store.mat(j), d, &e, &mut tmp, mode),
                self.store.log_det(j),
                d,
            );
            terms.push(ll + (self.store.sp(j) / self.total_sp).ln());
        }
        logsumexp_tree(&terms)
    }

    /// The component-outer blocked sweep shared by the density and
    /// posterior batch surfaces: fill each query's per-component term
    /// row (`ln N(x_bi; μ_j, Λ_j) + offset(j)`) block by block, then
    /// reduce every row to one result. One copy of the block/chunk
    /// indexing, so the two read paths cannot drift.
    fn blocked_term_rows<R>(
        &self,
        xs: &[Vec<f64>],
        offset: impl Fn(usize) -> f64,
        mut reduce: impl FnMut(&[f64]) -> R,
    ) -> Vec<R> {
        let k = self.store.len();
        let d = self.cfg.dim;
        let mode = self.cfg.kernel_mode;
        for x in xs {
            assert_eq!(x.len(), d, "batch scoring: dimensionality mismatch");
        }
        let mut blk = ScoreBlock::new(d, xs.len(), mode);
        let mut terms = vec![0.0; SCORE_BLOCK.min(xs.len()) * k];
        let mut out = Vec::with_capacity(xs.len());
        for block in xs.chunks(SCORE_BLOCK) {
            let b = block.len();
            for j in 0..k {
                let q = blk.component_terms(
                    self.store.mat(j),
                    self.store.mean(j),
                    self.store.log_det(j),
                    block,
                    offset(j),
                    mode,
                );
                for (bi, &t) in q.iter().enumerate() {
                    terms[bi * k + j] = t;
                }
            }
            out.extend((0..b).map(|bi| reduce(&terms[bi * k..(bi + 1) * k])));
        }
        out
    }

    /// The replica tier's analog of [`ModelSnapshot::blocked_term_rows`]:
    /// identical block/chunk structure, but each block's queries are
    /// narrowed to f32 once and every component term comes from the f32
    /// multi-query kernel over the replica arenas.
    fn blocked_term_rows_f32<R>(
        &self,
        rep: &ReplicaStore,
        xs: &[Vec<f64>],
        offset: impl Fn(usize) -> f64,
        mut reduce: impl FnMut(&[f64]) -> R,
    ) -> Vec<R> {
        let k = self.store.len();
        let d = self.cfg.dim;
        for x in xs {
            assert_eq!(x.len(), d, "batch scoring: dimensionality mismatch");
        }
        let mut blk = ReplicaBlock::new(d, xs.len());
        let mut terms = vec![0.0; SCORE_BLOCK.min(xs.len()) * k];
        let mut out = Vec::with_capacity(xs.len());
        for block in xs.chunks(SCORE_BLOCK) {
            let b = block.len();
            blk.load_queries(block);
            for j in 0..k {
                let q = blk.component_terms(rep, j, self.store.log_det(j), b, offset(j));
                for (bi, &t) in q.iter().enumerate() {
                    terms[bi * k + j] = t;
                }
            }
            out.extend((0..b).map(|bi| reduce(&terms[bi * k..(bi + 1) * k])));
        }
        out
    }

    /// Joint log-densities for a batch — bit-identical to mapping
    /// [`ModelSnapshot::log_density`], computed component-outer over
    /// [`SCORE_BLOCK`]-query blocks so each packed component row is
    /// streamed once per block instead of once per query (cross-call
    /// parallelism still comes from concurrent scorer threads).
    pub fn score_batch(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        if xs.is_empty() {
            return Vec::new();
        }
        assert!(!self.store.is_empty(), "score_batch on empty snapshot");
        if self.active_index().is_some() {
            // Candidate sets differ per query, so there is no shared
            // component-outer block to stream; top-C serving is the
            // per-point map (`O(C·D²)` each, cross-call parallelism
            // from concurrent scorer threads).
            return xs.iter().map(|x| self.log_density(x)).collect();
        }
        if let Some(rep) = &self.replica {
            return self.blocked_term_rows_f32(
                rep,
                xs,
                |j| (self.store.sp(j) / self.total_sp).ln(),
                logsumexp_tree,
            );
        }
        self.blocked_term_rows(
            xs,
            |j| (self.store.sp(j) / self.total_sp).ln(),
            logsumexp_tree,
        )
    }

    /// Posterior responsibilities for a batch — bit-identical to mapping
    /// [`ModelSnapshot::posteriors`], on the same component-outer
    /// blocked sweep as [`ModelSnapshot::score_batch`].
    pub fn posteriors_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        if xs.is_empty() {
            return Vec::new();
        }
        if self.active_index().is_some() {
            return xs.iter().map(|x| self.posteriors(x)).collect();
        }
        if let Some(rep) = &self.replica {
            return self.blocked_term_rows_f32(rep, xs, |_| 0.0, |row| {
                softmax_posteriors(row, self.store.sps())
            });
        }
        self.blocked_term_rows(xs, |_| 0.0, |row| softmax_posteriors(row, self.store.sps()))
    }

    /// Conditional reconstruction of the `target_idx` elements —
    /// bit-identical to [`super::IncrementalMixture::predict`] on the
    /// source model.
    pub fn predict(
        &self,
        known_vals: &[f64],
        known_idx: &[usize],
        target_idx: &[usize],
    ) -> Vec<f64> {
        assert_eq!(known_vals.len(), known_idx.len());
        assert!(!self.store.is_empty(), "predict on empty snapshot");
        let k = self.store.len();
        let d = self.cfg.dim;
        let mut log_liks = vec![0.0; k];
        let mut recons: Vec<Vec<f64>> = vec![Vec::new(); k];
        for (j, (llj, rcj)) in log_liks.iter_mut().zip(recons.iter_mut()).enumerate() {
            let r = precision_conditional(
                self.store.mat(j),
                d,
                self.store.mean(j),
                self.store.log_det(j),
                known_vals,
                known_idx,
                target_idx,
            );
            *llj = r.log_lik;
            *rcj = r.reconstruction;
        }
        let post = softmax_posteriors(&log_liks, self.store.sps());
        let mut out = vec![0.0; target_idx.len()];
        for (p, r) in post.iter().zip(recons.iter()) {
            for (o, &v) in out.iter_mut().zip(r.iter()) {
                *o += p * v;
            }
        }
        out
    }

    /// Conditional reconstructions for a batch sharing one index split —
    /// bit-identical to mapping [`ModelSnapshot::predict`]. Component-
    /// outer over query blocks: each component's `Λ` entries are
    /// streamed once per block, and its target-block Cholesky is
    /// factorized **once per call** (or reused from the factors cached
    /// at construction when the split is the recorded class split)
    /// instead of once per (component, block) — see
    /// [`precision_conditional_multi_with`].
    pub fn predict_batch(
        &self,
        known_vals: &[Vec<f64>],
        known_idx: &[usize],
        target_idx: &[usize],
    ) -> Vec<Vec<f64>> {
        if known_vals.is_empty() {
            return Vec::new();
        }
        assert!(!self.store.is_empty(), "predict_batch on empty snapshot");
        let k = self.store.len();
        let d = self.cfg.dim;
        let sps = self.store.sps();
        // Hoisted per-component factors: the cached class-split set when
        // this call targets the recorded split, otherwise computed once
        // here and shared by every query block.
        let computed: Vec<Cholesky>;
        let factors: &[Cholesky] =
            if !self.split_factors.is_empty() && target_idx == &self.class_idx[..] {
                &self.split_factors
            } else {
                computed = (0..k)
                    .map(|j| target_block_cholesky(self.store.mat(j), d, target_idx))
                    .collect();
                &computed
            };
        let mut out = Vec::with_capacity(known_vals.len());
        // Per-block buffers hoisted out of the loop; every (query,
        // component) slot is overwritten before it is read, so reuse
        // across blocks is safe.
        let bmax = SCORE_BLOCK.min(known_vals.len());
        let mut log_liks = vec![0.0; bmax * k];
        let mut recons: Vec<Vec<f64>> = vec![Vec::new(); bmax * k];
        for block in known_vals.chunks(SCORE_BLOCK) {
            let b = block.len();
            for j in 0..k {
                let conds = precision_conditional_multi_with(
                    self.store.mat(j),
                    d,
                    self.store.mean(j),
                    self.store.log_det(j),
                    block,
                    known_idx,
                    target_idx,
                    &factors[j],
                );
                for (bi, c) in conds.into_iter().enumerate() {
                    log_liks[bi * k + j] = c.log_lik;
                    recons[bi * k + j] = c.reconstruction;
                }
            }
            for bi in 0..b {
                let post = softmax_posteriors(&log_liks[bi * k..(bi + 1) * k], sps);
                let mut acc = vec![0.0; target_idx.len()];
                for (p, r) in post.iter().zip(recons[bi * k..(bi + 1) * k].iter()) {
                    for (o, &v) in acc.iter_mut().zip(r.iter()) {
                        *o += p * v;
                    }
                }
                out.push(acc);
            }
        }
        out
    }

    /// Posterior responsibilities `p(j|x)` — bit-identical to
    /// [`super::IncrementalMixture::posteriors`] on the source model.
    pub fn posteriors(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cfg.dim, "posteriors: dimensionality mismatch");
        if let Some((index, c)) = self.active_index() {
            // Full-length posterior vector (API shape contract), with
            // the mass renormalized over the candidate set and zeros
            // everywhere else — same convention as the live model.
            let (cands, ll) = self.topc_loglik(index, x, c);
            let sps: Vec<f64> = cands.iter().map(|&j| self.store.sp(j as usize)).collect();
            let post = softmax_posteriors(&ll, &sps);
            let mut out = vec![0.0; self.store.len()];
            for (&j, &p) in cands.iter().zip(post.iter()) {
                out[j as usize] = p;
            }
            return out;
        }
        if let Some(rep) = &self.replica {
            let mut blk = ReplicaBlock::new(self.cfg.dim, 1);
            blk.load_query(x);
            let mut ll = Vec::with_capacity(self.store.len());
            for j in 0..self.store.len() {
                ll.push(blk.component_terms(rep, j, self.store.log_det(j), 1, 0.0)[0]);
            }
            return softmax_posteriors(&ll, self.store.sps());
        }
        let d = self.cfg.dim;
        let mode = self.cfg.kernel_mode;
        let mut e = vec![0.0; d];
        let mut tmp = vec![0.0; if mode == KernelMode::Fast { d } else { 0 }];
        let mut ll = Vec::with_capacity(self.store.len());
        for j in 0..self.store.len() {
            sub_into(x, self.store.mean(j), &mut e);
            ll.push(log_gaussian(
                packed::quad_form_scratch(self.store.mat(j), d, &e, &mut tmp, mode),
                self.store.log_det(j),
                d,
            ));
        }
        softmax_posteriors(&ll, self.store.sps())
    }

    /// Classifier scores for the recorded feature/class split —
    /// bit-identical to `SupervisedGmm::class_scores` on the source
    /// model (the index split is precomputed at construction). Panics
    /// unless the snapshot was taken through `SupervisedGmm::snapshot`
    /// (or [`ModelSnapshot::with_split`]).
    pub fn class_scores(&self, features: &[f64]) -> Vec<f64> {
        assert!(self.n_classes > 0, "snapshot has no class split");
        assert_eq!(features.len(), self.n_features);
        clip_normalize(self.predict(features, &self.feature_idx, &self.class_idx))
    }

    /// Batched [`ModelSnapshot::class_scores`], routed through the
    /// blocked [`ModelSnapshot::predict_batch`] — bit-identical to the
    /// per-point mapping.
    pub fn class_scores_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        if xs.is_empty() {
            return Vec::new();
        }
        assert!(self.n_classes > 0, "snapshot has no class split");
        for x in xs {
            assert_eq!(x.len(), self.n_features);
        }
        self.predict_batch(xs, &self.feature_idx, &self.class_idx)
            .into_iter()
            .map(clip_normalize)
            .collect()
    }
}

/// Per-component `W = Λ_tt` Cholesky factors for a recorded class
/// split, precomputed once at snapshot construction so the serving
/// conditional path (`class_scores_batch`) never re-factorizes inside
/// the per-(component, block) loop. Empty when there is no class split
/// (or no components yet).
fn split_factors(store: &ComponentStore, dim: usize, class_idx: &[usize]) -> Vec<Cholesky> {
    if class_idx.is_empty() {
        return Vec::new();
    }
    (0..store.len())
        .map(|j| target_block_cholesky(store.mat(j), dim, class_idx))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::{Figmn, GmmConfig, IncrementalMixture, SearchMode};
    use crate::gmm::supervised::supervised_figmn;
    use crate::rng::Pcg64;

    fn trained_model(n: usize) -> (Figmn, Vec<Vec<f64>>) {
        let cfg = GmmConfig::new(3).with_delta(0.4).with_beta(0.1).without_pruning();
        let mut m = Figmn::new(cfg, &[2.0, 2.0, 2.0]);
        let mut rng = Pcg64::seed(21);
        let centers = [[0.0, 0.0, 0.0], [8.0, 8.0, 0.0], [0.0, 8.0, 8.0]];
        let mut stream = Vec::new();
        for i in 0..n {
            let c = &centers[i % 3];
            let x: Vec<f64> = c.iter().map(|&v| v + rng.normal() * 0.6).collect();
            m.learn(&x);
            stream.push(x);
        }
        (m, stream)
    }

    #[test]
    fn snapshot_scoring_is_bit_identical_to_serial_model() {
        let (m, stream) = trained_model(120);
        let snap = m.snapshot();
        assert_eq!(snap.num_components(), m.num_components());
        assert_eq!(snap.points_seen(), m.points_seen());
        assert_eq!(snap.model_bytes(), m.model_bytes());
        let probes: Vec<Vec<f64>> = stream.iter().rev().take(10).cloned().collect();
        for x in &probes {
            assert!(snap.log_density(x) == m.log_density(x), "log_density bits differ");
            assert_eq!(snap.posteriors(x), m.posteriors(x));
            assert_eq!(
                snap.predict(&x[..2], &[0, 1], &[2]),
                m.predict(&x[..2], &[0, 1], &[2])
            );
        }
        let expect: Vec<f64> = probes.iter().map(|x| m.log_density(x)).collect();
        assert_eq!(snap.score_batch(&probes), expect);
        let knowns: Vec<Vec<f64>> = probes.iter().map(|x| x[..2].to_vec()).collect();
        assert_eq!(
            snap.predict_batch(&knowns, &[0, 1], &[2]),
            knowns.iter().map(|kv| m.predict(kv, &[0, 1], &[2])).collect::<Vec<_>>()
        );
    }

    /// The blocked batch surfaces stay bit-identical to the per-point
    /// mappings across block boundaries (batch > SCORE_BLOCK, ragged
    /// tail included).
    #[test]
    fn blocked_batches_match_per_point_across_boundaries() {
        let (m, stream) = trained_model(150);
        let snap = m.snapshot();
        // 70 probes = two full 32-blocks + a 6-query tail.
        let probes: Vec<Vec<f64>> = stream.iter().rev().take(70).cloned().collect();
        let expect: Vec<f64> = probes.iter().map(|x| snap.log_density(x)).collect();
        assert_eq!(snap.score_batch(&probes), expect);
        let expect_post: Vec<Vec<f64>> = probes.iter().map(|x| snap.posteriors(x)).collect();
        assert_eq!(snap.posteriors_batch(&probes), expect_post);
        let knowns: Vec<Vec<f64>> = probes.iter().map(|x| x[..2].to_vec()).collect();
        assert_eq!(
            snap.predict_batch(&knowns, &[0, 1], &[2]),
            knowns.iter().map(|kv| snap.predict(kv, &[0, 1], &[2])).collect::<Vec<_>>()
        );
        // Empty batches stay empty.
        assert!(snap.score_batch(&[]).is_empty());
        assert!(snap.posteriors_batch(&[]).is_empty());
        assert!(snap.predict_batch(&[], &[0, 1], &[2]).is_empty());
    }

    /// A TopC snapshot serves from an index frozen at publish:
    /// batch surfaces are the per-point maps bit-for-bit, posteriors
    /// restrict their support to ≤ C candidates, two snapshots of the
    /// same state agree bitwise, and scores stay tolerance-equivalent
    /// to a strict model trained on the same well-separated stream.
    #[test]
    fn topc_snapshot_serves_from_frozen_index() {
        let mk = |mode: SearchMode| {
            GmmConfig::new(3)
                .with_delta(0.4)
                .with_beta(0.1)
                .without_pruning()
                .with_search_mode(mode)
        };
        let mut topc = Figmn::new(mk(SearchMode::TopC { c: 2 }), &[2.0, 2.0, 2.0]);
        let mut strict = Figmn::new(mk(SearchMode::Strict), &[2.0, 2.0, 2.0]);
        let mut rng = Pcg64::seed(77);
        let centers = [[0.0, 0.0, 0.0], [40.0, 40.0, 0.0], [0.0, 40.0, 40.0]];
        let mut stream = Vec::new();
        for i in 0..150 {
            let c = &centers[i % 3];
            let x: Vec<f64> = c.iter().map(|&v| v + rng.normal() * 0.5).collect();
            assert_eq!(topc.learn(&x), strict.learn(&x), "decisions must be exact");
            stream.push(x);
        }
        assert_eq!(topc.num_components(), strict.num_components());
        let snap = topc.snapshot();
        let snap2 = topc.snapshot();
        let probes: Vec<Vec<f64>> = stream.iter().rev().take(12).cloned().collect();
        for x in &probes {
            let ld = snap.log_density(x);
            assert!(ld == snap2.log_density(x), "snapshots of one state must agree");
            let rel = (ld - strict.log_density(x)).abs() / strict.log_density(x).abs().max(1.0);
            assert!(rel < 1e-6, "top-C tail loss out of tolerance: rel={rel}");
            let post = snap.posteriors(x);
            assert_eq!(post.len(), snap.num_components());
            assert!(post.iter().filter(|&&p| p > 0.0).count() <= 2);
            let sum: f64 = post.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12);
        }
        let expect: Vec<f64> = probes.iter().map(|x| snap.log_density(x)).collect();
        assert_eq!(snap.score_batch(&probes), expect);
        let expect_post: Vec<Vec<f64>> = probes.iter().map(|x| snap.posteriors(x)).collect();
        assert_eq!(snap.posteriors_batch(&probes), expect_post);
    }

    /// A replica-carrying snapshot serves the density surfaces from the
    /// f32 arenas: within tolerance of the f64 path, deterministic, and
    /// batch ≡ per-point per query; `ReplicaMode::Off` stays bitwise
    /// identical to the pre-replica path (the full property sweep lives
    /// in `tests/replica_equivalence.rs`).
    #[test]
    fn replica_snapshot_serves_within_tolerance() {
        use crate::gmm::ReplicaMode;
        let cfg = GmmConfig::new(3).with_delta(0.4).with_beta(0.1).without_pruning();
        let mut plain = Figmn::new(cfg.clone(), &[2.0, 2.0, 2.0]);
        let mut rep =
            Figmn::new(cfg.with_replica_mode(ReplicaMode::f32_default()), &[2.0, 2.0, 2.0]);
        let mut rng = Pcg64::seed(45);
        let centers = [[0.0, 0.0, 0.0], [8.0, 8.0, 0.0], [0.0, 8.0, 8.0]];
        let mut stream = Vec::new();
        for i in 0..120 {
            let c = &centers[i % 3];
            let x: Vec<f64> = c.iter().map(|&v| v + rng.normal() * 0.6).collect();
            assert_eq!(plain.learn(&x), rep.learn(&x), "write path must be unaffected");
            stream.push(x);
        }
        let snap_f64 = plain.snapshot();
        let snap_f32 = rep.snapshot();
        assert!(!snap_f64.has_replica());
        assert_eq!(snap_f64.replica_bytes(), 0);
        assert!(snap_f32.has_replica());
        assert!(snap_f32.replica_bytes() > 0);
        let probes: Vec<Vec<f64>> = stream.iter().rev().take(40).cloned().collect();
        let tol = ReplicaMode::f32_default().tol().unwrap();
        for x in &probes {
            let f64_ld = snap_f64.log_density(x);
            let f32_ld = snap_f32.log_density(x);
            let rel = (f32_ld - f64_ld).abs() / f64_ld.abs().max(1.0);
            assert!(rel <= tol, "replica log_density out of tolerance: rel={rel}");
        }
        // Batch surfaces equal the per-point maps, bitwise (blocking
        // never changes a query's f32 sequence either).
        let expect: Vec<f64> = probes.iter().map(|x| snap_f32.log_density(x)).collect();
        assert_eq!(snap_f32.score_batch(&probes), expect);
        let expect_post: Vec<Vec<f64>> = probes.iter().map(|x| snap_f32.posteriors(x)).collect();
        assert_eq!(snap_f32.posteriors_batch(&probes), expect_post);
        // Conditional inference stays on the f64 path: both snapshots
        // agree bit for bit.
        for x in probes.iter().take(5) {
            assert_eq!(
                snap_f32.predict(&x[..2], &[0, 1], &[2]),
                snap_f64.predict(&x[..2], &[0, 1], &[2])
            );
        }
    }

    #[test]
    fn snapshot_is_isolated_from_later_learns() {
        let (mut m, stream) = trained_model(60);
        let snap = m.snapshot();
        let before = snap.log_density(&stream[0]);
        // Keep learning on the live model; the snapshot must not move.
        for x in stream.iter().take(30) {
            m.learn(x);
        }
        assert!(snap.log_density(&stream[0]) == before);
        assert_eq!(snap.staleness(m.points_seen()), 30);
    }

    #[test]
    fn supervised_snapshot_matches_class_scores() {
        let cfg = GmmConfig::new(2).with_delta(0.5).with_beta(0.05).without_pruning();
        let mut clf = supervised_figmn(cfg, &[3.0, 3.0], 3);
        let mut rng = Pcg64::seed(5);
        let centers = [[0.0, 0.0], [7.0, 7.0], [0.0, 7.0]];
        for i in 0..150 {
            let c = i % 3;
            let x = vec![
                centers[c][0] + rng.normal() * 0.7,
                centers[c][1] + rng.normal() * 0.7,
            ];
            clf.train_one(&x, c);
        }
        let snap = clf.snapshot().expect("trained model must snapshot");
        assert_eq!(snap.n_features(), 2);
        assert_eq!(snap.n_classes(), 3);
        for i in 0..20 {
            let c = i % 3;
            let x = vec![
                centers[c][0] + rng.normal() * 0.7,
                centers[c][1] + rng.normal() * 0.7,
            ];
            assert_eq!(snap.class_scores(&x), clf.class_scores(&x));
        }
        assert_eq!(
            snap.class_scores_batch(&[vec![0.0, 0.0], vec![7.0, 7.0]]),
            vec![clf.class_scores(&[0.0, 0.0]), clf.class_scores(&[7.0, 7.0])]
        );
    }

    #[test]
    fn empty_model_has_no_snapshot() {
        let cfg = GmmConfig::new(2).with_delta(0.5).with_beta(0.05).without_pruning();
        let clf = supervised_figmn(cfg, &[1.0, 1.0], 2);
        assert!(clf.snapshot().is_none());
    }

    #[test]
    #[should_panic]
    fn split_must_tile_dim() {
        let (m, _) = trained_model(30);
        let _ = m.snapshot().with_split(1, 1); // dim is 3
    }
}
