//! Conditional (supervised) inference for both IGMN variants.
//!
//! Paper §2.4 (covariance form, Eq. 15) and §3 (precision form via block
//! matrix decomposition, Eq. 27). The FIGMN path never touches the
//! covariance matrix: with the joint precision partitioned over
//! known(i)/target(t) indices as
//!
//! ```text
//! Λ = [ X  Y ]      (X: i×i,  Y: i×t,  W: t×t)
//!     [ Yᵀ W ]
//! ```
//!
//! the paper's identity `Y·W⁻¹ = −A⁻¹·B` gives the conditional mean
//! `x̂_t = μ_t − W⁻¹·Yᵀ·(x_i − μ_i)`, and the Schur complement gives the
//! *marginal* of the known block for Eq. 14:
//! `A⁻¹ = X − Y·W⁻¹·Yᵀ` and `log|A| = log|C| + log|W|`.
//!
//! Only `W` (t×t, t = number of outputs, usually ≪ D) is ever factorized —
//! the `O(o³)` the paper accepts in §3's closing discussion.
//!
//! Both conditionals read the joint matrix from the **packed
//! upper-triangular** component arenas (see [`crate::linalg::packed`]):
//! every `(i, j)` access goes through the symmetric accessor, which
//! returns exactly the value the dense (exactly symmetric) matrix held,
//! so results are bit-identical to the dense formulation.

use super::log_gaussian;
use crate::linalg::packed::sym_at;
use crate::linalg::{dot, Cholesky, Matrix};

/// Per-component conditional result.
#[derive(Debug, Clone)]
pub struct Conditional {
    /// `ln p(x_i | j)` — marginal likelihood of the known elements.
    pub log_lik: f64,
    /// Conditional mean of the target elements `E[x_t | x_i, j]`.
    pub reconstruction: Vec<f64>,
}

/// Precision-form conditional (FIGMN, Eq. 27 + Schur marginal).
///
/// `lambda` is the joint precision in packed upper-triangular form
/// (length `dim·(dim+1)/2`), `log_det` is `log|C|` (covariance
/// determinant), `known_vals[k]` is the value of joint element
/// `known_idx[k]`.
pub fn precision_conditional(
    lambda: &[f64],
    dim: usize,
    mean: &[f64],
    log_det: f64,
    known_vals: &[f64],
    known_idx: &[usize],
    target_idx: &[usize],
) -> Conditional {
    let ni = known_idx.len();
    let nt = target_idx.len();
    debug_assert_eq!(known_vals.len(), ni);
    debug_assert_eq!(lambda.len(), crate::linalg::packed::packed_len(dim));

    // d = x_i − μ_i
    let mut d = vec![0.0; ni];
    for (k, (&idx, &v)) in known_idx.iter().zip(known_vals.iter()).enumerate() {
        d[k] = v - mean[idx];
    }

    // yTd = Yᵀ·d  (t-vector), X·d quadratic form on the fly.
    let mut ytd = vec![0.0; nt];
    for (r, &ti) in target_idx.iter().enumerate() {
        let mut acc = 0.0;
        for (k, &ki) in known_idx.iter().enumerate() {
            acc += sym_at(lambda, dim, ki, ti) * d[k];
        }
        ytd[r] = acc;
    }
    let mut dxd = 0.0;
    for (a, &ia) in known_idx.iter().enumerate() {
        let mut acc = 0.0;
        for (b, &ib) in known_idx.iter().enumerate() {
            acc += sym_at(lambda, dim, ia, ib) * d[b];
        }
        dxd += d[a] * acc;
    }

    // W (t×t) and its Cholesky.
    let mut w = Matrix::zeros(nt, nt);
    for (a, &ta) in target_idx.iter().enumerate() {
        for (b, &tb) in target_idx.iter().enumerate() {
            w[(a, b)] = sym_at(lambda, dim, ta, tb);
        }
    }
    let chol = Cholesky::new(&w)
        .expect("W = Λ_tt must be PD for a PD joint precision");

    // z = W⁻¹·yTd ; conditional mean x̂_t = μ_t − z.
    let z = chol.solve(&ytd);
    let mut recon = vec![0.0; nt];
    for (r, &ti) in target_idx.iter().enumerate() {
        recon[r] = mean[ti] - z[r];
    }

    // Marginal Mahalanobis: dᵀ(X − Y·W⁻¹·Yᵀ)d = dᵀXd − yTdᵀ·W⁻¹·yTd.
    let d2 = dxd - dot(&ytd, &z);
    // log|A| = log|C| + log|W|.
    let log_det_a = log_det + chol.log_det();
    Conditional { log_lik: log_gaussian(d2.max(0.0), log_det_a, ni), reconstruction: recon }
}

/// The Cholesky factor of a component's target block `W = Λ_tt`.
///
/// `W` depends only on the component's precision and the target split —
/// not on the queries or the block — so batch callers compute it **once
/// per component per call** (and [`super::ModelSnapshot`] caches it for
/// its recorded split) instead of once per (component, block) as the
/// pre-hoist code did. Reads `Λ` through the symmetric accessor in the
/// same `(a, b)` order as the scalar path, so the factor — and
/// everything derived from it — is bit-identical.
pub fn target_block_cholesky(lambda: &[f64], dim: usize, target_idx: &[usize]) -> Cholesky {
    let nt = target_idx.len();
    let mut w = Matrix::zeros(nt, nt);
    for (a, &ta) in target_idx.iter().enumerate() {
        for (c, &tb) in target_idx.iter().enumerate() {
            w[(a, c)] = sym_at(lambda, dim, ta, tb);
        }
    }
    Cholesky::new(&w).expect("W = Λ_tt must be PD for a PD joint precision")
}

/// Block-batched [`precision_conditional`]: conditionals for a block of
/// query rows sharing one known/target split, against one component.
///
/// The scalar path re-reads every `Λ(k,t)`/`Λ(a,b)` entry and
/// re-factorizes the target block `W` once *per query*; this variant
/// streams each matrix entry once per **block** (applying it to every
/// query while hot) and takes `W`'s factor precomputed by
/// [`target_block_cholesky`] — hoisted all the way to once per
/// (component, call) by the batch surfaces. Per query, the
/// floating-point operations run in the scalar path's order with
/// per-query accumulators, so each returned [`Conditional`] is
/// **bit-identical** to calling [`precision_conditional`] on that row
/// alone.
#[allow(clippy::too_many_arguments)]
pub fn precision_conditional_multi_with(
    lambda: &[f64],
    dim: usize,
    mean: &[f64],
    log_det: f64,
    known_vals_block: &[Vec<f64>],
    known_idx: &[usize],
    target_idx: &[usize],
    chol: &Cholesky,
) -> Vec<Conditional> {
    let b = known_vals_block.len();
    let ni = known_idx.len();
    let nt = target_idx.len();
    debug_assert_eq!(lambda.len(), crate::linalg::packed::packed_len(dim));

    // Residuals d = x_i − μ_i, per query (b×ni).
    let mut dev = vec![0.0; b * ni];
    for (bi, kv) in known_vals_block.iter().enumerate() {
        assert_eq!(kv.len(), ni, "conditional block: known_vals row length");
        let row = &mut dev[bi * ni..(bi + 1) * ni];
        for (k, (&idx, &v)) in known_idx.iter().zip(kv.iter()).enumerate() {
            row[k] = v - mean[idx];
        }
    }

    // ytd = Yᵀ·d per query (b×nt): each Λ(k,t) entry is read once per
    // block; every query folds it in ascending-k order, exactly like
    // the scalar path.
    let mut ytd = vec![0.0; b * nt];
    for (r, &ti) in target_idx.iter().enumerate() {
        for (k, &ki) in known_idx.iter().enumerate() {
            let a = sym_at(lambda, dim, ki, ti);
            for bi in 0..b {
                ytd[bi * nt + r] += a * dev[bi * ni + k];
            }
        }
    }

    // dᵀ·X·d per query, X streamed once per block (inner accumulators
    // reset per row, ascending-index folds — the scalar order).
    let mut dxd = vec![0.0; b];
    let mut acc = vec![0.0; b];
    for (a_row, &ia) in known_idx.iter().enumerate() {
        acc.fill(0.0);
        for (a_col, &ib) in known_idx.iter().enumerate() {
            let m = sym_at(lambda, dim, ia, ib);
            for bi in 0..b {
                acc[bi] += m * dev[bi * ni + a_col];
            }
        }
        for bi in 0..b {
            dxd[bi] += dev[bi * ni + a_row] * acc[bi];
        }
    }

    let log_det_a = log_det + chol.log_det();

    (0..b)
        .map(|bi| {
            let ytd_q = &ytd[bi * nt..(bi + 1) * nt];
            let z = chol.solve(ytd_q);
            let mut recon = vec![0.0; nt];
            for (r, &ti) in target_idx.iter().enumerate() {
                recon[r] = mean[ti] - z[r];
            }
            let d2 = dxd[bi] - dot(ytd_q, &z);
            Conditional {
                log_lik: log_gaussian(d2.max(0.0), log_det_a, ni),
                reconstruction: recon,
            }
        })
        .collect()
}

/// [`precision_conditional_multi_with`] with the target-block factor
/// computed inline — the convenience form for one-shot callers (and the
/// test oracle for the hoisted variant).
pub fn precision_conditional_multi(
    lambda: &[f64],
    dim: usize,
    mean: &[f64],
    log_det: f64,
    known_vals_block: &[Vec<f64>],
    known_idx: &[usize],
    target_idx: &[usize],
) -> Vec<Conditional> {
    let chol = target_block_cholesky(lambda, dim, target_idx);
    precision_conditional_multi_with(
        lambda,
        dim,
        mean,
        log_det,
        known_vals_block,
        known_idx,
        target_idx,
        &chol,
    )
}

/// Covariance-form conditional (original IGMN, Eq. 15). Factorizes the
/// known-block covariance `C_i` per call — the `O(D³)` the paper
/// removes. `cov` is the joint covariance in packed upper-triangular
/// form (length `dim·(dim+1)/2`).
pub fn covariance_conditional(
    cov: &[f64],
    dim: usize,
    mean: &[f64],
    known_vals: &[f64],
    known_idx: &[usize],
    target_idx: &[usize],
) -> Conditional {
    let ni = known_idx.len();
    let nt = target_idx.len();
    debug_assert_eq!(known_vals.len(), ni);
    debug_assert_eq!(cov.len(), crate::linalg::packed::packed_len(dim));

    let mut d = vec![0.0; ni];
    for (k, (&idx, &v)) in known_idx.iter().zip(known_vals.iter()).enumerate() {
        d[k] = v - mean[idx];
    }

    let mut c_i = Matrix::zeros(ni, ni);
    for (a, &ia) in known_idx.iter().enumerate() {
        for (b, &ib) in known_idx.iter().enumerate() {
            c_i[(a, b)] = sym_at(cov, dim, ia, ib);
        }
    }
    let chol = Cholesky::new(&c_i).expect("C_i must be PD for a PD joint covariance");
    // s = C_i⁻¹·d
    let s = chol.solve(&d);
    // x̂_t = μ_t + C_ti·s  (Eq. 15)
    let mut recon = vec![0.0; nt];
    for (r, &ti) in target_idx.iter().enumerate() {
        let mut acc = 0.0;
        for (k, &ki) in known_idx.iter().enumerate() {
            acc += sym_at(cov, dim, ti, ki) * s[k];
        }
        recon[r] = mean[ti] + acc;
    }

    let d2 = dot(&d, &s);
    Conditional { log_lik: log_gaussian(d2.max(0.0), chol.log_det(), ni), reconstruction: recon }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::packed::pack_symmetric;
    use crate::testutil::{assert_close, assert_rel, check, random_spd};

    /// The paper's §3 block-decomposition identity: precision-form and
    /// covariance-form conditionals agree on random PD joints and random
    /// known/target partitions.
    #[test]
    fn precision_equals_covariance_conditional() {
        check(60, |rng| {
            let n = 3 + rng.below(6);
            let cov = random_spd(n, rng);
            let mut lambda = cov.inverse().unwrap();
            lambda.symmetrize();
            let log_det = cov.determinant().ln();
            let mean: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

            // Random partition: at least 1 known, at least 1 target.
            let perm = rng.permutation(n);
            let split = 1 + rng.below(n - 1);
            let mut known: Vec<usize> = perm[..split].to_vec();
            let mut target: Vec<usize> = perm[split..].to_vec();
            known.sort_unstable();
            target.sort_unstable();
            let known_vals: Vec<f64> = known.iter().map(|&i| mean[i] + rng.normal()).collect();

            let mut cov_sym = cov.clone();
            cov_sym.symmetrize();
            let lambda_p = pack_symmetric(&lambda);
            let cov_p = pack_symmetric(&cov_sym);
            let a = precision_conditional(
                &lambda_p, n, &mean, log_det, &known_vals, &known, &target,
            );
            let b = covariance_conditional(&cov_p, n, &mean, &known_vals, &known, &target);
            assert_close(&a.reconstruction, &b.reconstruction, 1e-7);
            assert_rel(a.log_lik, b.log_lik, 1e-7);
        });
    }

    /// The block-batched conditional equals the per-query scalar path
    /// bit for bit — every field, across random joints, splits, and
    /// block sizes (including size 1 and tile-tail sizes).
    #[test]
    fn multi_conditional_bit_identical_to_per_point() {
        check(40, |rng| {
            let n = 3 + rng.below(6);
            let cov = random_spd(n, rng);
            let mut lambda = cov.inverse().unwrap();
            lambda.symmetrize();
            let log_det = cov.determinant().ln();
            let mean: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

            let perm = rng.permutation(n);
            let split = 1 + rng.below(n - 1);
            let mut known: Vec<usize> = perm[..split].to_vec();
            let mut target: Vec<usize> = perm[split..].to_vec();
            known.sort_unstable();
            target.sort_unstable();

            let b = 1 + rng.below(7);
            let block: Vec<Vec<f64>> = (0..b)
                .map(|_| known.iter().map(|&i| mean[i] + rng.normal()).collect())
                .collect();

            let lambda_p = pack_symmetric(&lambda);
            let multi = precision_conditional_multi(
                &lambda_p, n, &mean, log_det, &block, &known, &target,
            );
            assert_eq!(multi.len(), b);
            for (bi, kv) in block.iter().enumerate() {
                let single =
                    precision_conditional(&lambda_p, n, &mean, log_det, kv, &known, &target);
                assert!(
                    multi[bi].log_lik.to_bits() == single.log_lik.to_bits(),
                    "block query {bi}: log_lik bits diverged"
                );
                assert_eq!(
                    multi[bi].reconstruction, single.reconstruction,
                    "block query {bi}: reconstruction diverged"
                );
            }
        });
    }

    /// A target-block factor computed once and reused across blocks is
    /// bit-identical to factorizing per block (the snapshot caches the
    /// factor for its recorded split — this is the contract it relies
    /// on).
    #[test]
    fn hoisted_factor_reuse_is_bit_identical() {
        check(20, |rng| {
            let n = 4 + rng.below(4);
            let cov = random_spd(n, rng);
            let mut lambda = cov.inverse().unwrap();
            lambda.symmetrize();
            let log_det = cov.determinant().ln();
            let mean: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let known: Vec<usize> = (0..n - 2).collect();
            let target = [n - 2, n - 1];
            let lambda_p = pack_symmetric(&lambda);
            let chol = target_block_cholesky(&lambda_p, n, &target);
            for _block in 0..3 {
                let b = 1 + rng.below(5);
                let block: Vec<Vec<f64>> = (0..b)
                    .map(|_| known.iter().map(|&i| mean[i] + rng.normal()).collect())
                    .collect();
                let hoisted = precision_conditional_multi_with(
                    &lambda_p, n, &mean, log_det, &block, &known, &target, &chol,
                );
                let inline = precision_conditional_multi(
                    &lambda_p, n, &mean, log_det, &block, &known, &target,
                );
                for (h, i) in hoisted.iter().zip(inline.iter()) {
                    assert!(h.log_lik.to_bits() == i.log_lik.to_bits());
                    assert_eq!(h.reconstruction, i.reconstruction);
                }
            }
        });
    }

    /// For a bivariate Gaussian with correlation ρ the conditional mean is
    /// μ₂ + ρ·(σ₂/σ₁)·(x₁ − μ₁) — check against the closed form.
    #[test]
    fn bivariate_closed_form() {
        let (s1, s2, rho) = (2.0, 0.5, 0.7);
        let cov = Matrix::from_rows(2, 2, &[s1 * s1, rho * s1 * s2, rho * s1 * s2, s2 * s2]);
        let mut lambda = cov.inverse().unwrap();
        lambda.symmetrize();
        let mean = [1.0, -1.0];
        let x1 = 3.0;
        let expect = mean[1] + rho * (s2 / s1) * (x1 - mean[0]);

        let lambda_p = pack_symmetric(&lambda);
        let r = precision_conditional(
            &lambda_p, 2, &mean, cov.determinant().ln(), &[x1], &[0], &[1],
        );
        assert_rel(r.reconstruction[0], expect, 1e-10);
        let r2 = covariance_conditional(&pack_symmetric(&cov), 2, &mean, &[x1], &[0], &[1]);
        assert_rel(r2.reconstruction[0], expect, 1e-10);
    }

    /// Marginal likelihood must equal a directly-constructed Gaussian on
    /// the known block.
    #[test]
    fn marginal_matches_direct() {
        check(30, |rng| {
            let n = 4 + rng.below(4);
            let cov = random_spd(n, rng);
            let mean: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let known: Vec<usize> = (0..n - 1).collect();
            let target = [n - 1];
            let kv: Vec<f64> = known.iter().map(|&i| mean[i] + 0.5 * rng.normal()).collect();

            let c_i = cov.submatrix(&known, &known);
            let chol = Cholesky::new(&c_i).unwrap();
            let d: Vec<f64> = known.iter().zip(kv.iter()).map(|(&i, &v)| v - mean[i]).collect();
            let expect = log_gaussian(chol.quad_form_inv(&d), chol.log_det(), known.len());

            let mut lambda = cov.inverse().unwrap();
            lambda.symmetrize();
            let r = precision_conditional(
                &pack_symmetric(&lambda),
                n,
                &mean,
                cov.determinant().ln(),
                &kv,
                &known,
                &target,
            );
            assert_rel(r.log_lik, expect, 1e-7);
        });
    }
}
