//! Query-block scratch for the batched scoring read path.
//!
//! Every batch scoring surface (`score_batch`, `posteriors_batch`,
//! `predict_batch`, `class_scores_batch` — on [`super::Figmn`],
//! [`super::ModelSnapshot`] and [`super::SupervisedGmm`]) runs
//! **component-outer / query-inner**: queries are grouped into blocks
//! of [`SCORE_BLOCK`], and each packed component row is streamed once
//! per block through the multi-query kernels of
//! [`crate::linalg::packed`] instead of once per query. At large `D`
//! the per-point path is memory-bound (each query re-streams all
//! `K·D(D+1)/2` packed doubles at ~1 flop/byte), so blocking raises
//! arithmetic intensity — and therefore throughput — by up to the
//! block factor; the `serving_read_path` and `layout_bandwidth`
//! benches quantify it.
//!
//! ## Equivalence contract
//!
//! Blocking reorders *which query* consumes a matrix value next, never
//! the floating-point operations within a query (see the multi-kernel
//! contract in [`crate::linalg::packed`]). Every blocked batch surface
//! therefore returns results **bit-identical to mapping its per-point
//! counterpart**, in both kernel modes — enforced by
//! `tests/blocked_scoring_equivalence.rs`.

use super::log_gaussian;
use crate::linalg::{packed, sub_into, KernelMode};

/// Queries per block. Sized so a block's per-row working set (the
/// packed row plus `SCORE_BLOCK` residual lanes) stays cache-resident
/// while the arithmetic-intensity gain saturates; fixed (rather than
/// adaptive) so results never depend on batch size.
pub(crate) const SCORE_BLOCK: usize = 32;

/// Floats of w-block kernel scratch a mode needs for a `b`-query block
/// at dimension `d`: the fast multi kernel assembles `w_q = Λ·e_q` per
/// query; the strict kernel reads none.
pub(crate) fn wblock_len(d: usize, b: usize, mode: KernelMode) -> usize {
    match mode {
        KernelMode::Strict => 0,
        KernelMode::Fast => b * d,
    }
}

/// Per-component log-density terms for one query block:
/// `terms[bi] = ln N(xs[bi]; mean, mat) + offset` for every query in
/// `xs` (at most [`SCORE_BLOCK`]).
///
/// `e` (≥ `b·d`) receives the residual block, `w` (≥
/// [`wblock_len`]) the fast path's mat-vec block, `terms` (≥ `b`) the
/// output. Per query, the operations are exactly the per-point scoring
/// sequence (`sub_into` → quadratic form → [`log_gaussian`] → `+
/// offset`), so the terms are bit-identical to the per-point path in
/// both modes.
#[allow(clippy::too_many_arguments)]
pub(crate) fn component_block_terms(
    mat: &[f64],
    mean: &[f64],
    log_det: f64,
    d: usize,
    xs: &[Vec<f64>],
    offset: f64,
    mode: KernelMode,
    e: &mut [f64],
    w: &mut [f64],
    terms: &mut [f64],
) {
    let b = xs.len();
    debug_assert!(b <= SCORE_BLOCK, "query block larger than SCORE_BLOCK");
    debug_assert!(e.len() >= b * d);
    debug_assert!(terms.len() >= b);
    for (bi, x) in xs.iter().enumerate() {
        sub_into(x, mean, &mut e[bi * d..(bi + 1) * d]);
    }
    packed::quad_form_multi_mode(
        mat,
        d,
        &e[..b * d],
        b,
        &mut w[..wblock_len(d, b, mode)],
        &mut terms[..b],
        mode,
    );
    for t in terms[..b].iter_mut() {
        *t = log_gaussian(*t, log_det, d) + offset;
    }
}

/// Owned scratch for the serial block-scoring paths (the engine's
/// sharded paths use each worker's `Scratch::split3` arena instead):
/// one residual block, one fast-mode w-block, one per-query term
/// buffer, all reused across every (component, block) pair of a batch.
pub(crate) struct ScoreBlock {
    d: usize,
    e: Vec<f64>,
    w: Vec<f64>,
    q: Vec<f64>,
}

impl ScoreBlock {
    /// Scratch for blocks of up to `min(queries, SCORE_BLOCK)` rows —
    /// sized to the batch, so a 1-query serving call doesn't allocate
    /// full 32-row buffers.
    pub(crate) fn new(d: usize, queries: usize, mode: KernelMode) -> ScoreBlock {
        let rows = queries.clamp(1, SCORE_BLOCK);
        ScoreBlock {
            d,
            e: vec![0.0; rows * d],
            w: vec![0.0; wblock_len(d, rows, mode)],
            q: vec![0.0; rows],
        }
    }

    /// [`component_block_terms`] against this scratch; returns the
    /// terms for the block's queries.
    pub(crate) fn component_terms(
        &mut self,
        mat: &[f64],
        mean: &[f64],
        log_det: f64,
        xs: &[Vec<f64>],
        offset: f64,
        mode: KernelMode,
    ) -> &[f64] {
        let b = xs.len();
        component_block_terms(
            mat,
            mean,
            log_det,
            self.d,
            xs,
            offset,
            mode,
            &mut self.e,
            &mut self.w,
            &mut self.q,
        );
        &self.q[..b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::packed::{pack_symmetric, quad_form};
    use crate::rng::Pcg64;
    use crate::testutil::random_spd;

    /// Block terms equal the per-point scoring sequence bit for bit in
    /// strict mode, and the fast path matches the fast per-point
    /// kernels (which `tests/blocked_scoring_equivalence.rs` exercises
    /// end to end).
    #[test]
    fn block_terms_match_per_point_sequence() {
        let d = 9;
        let mut rng = Pcg64::seed(17);
        let mut m = random_spd(d, &mut rng);
        m.symmetrize();
        let mat = pack_symmetric(&m);
        let mean: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let log_det = rng.normal();
        let offset = rng.normal();
        let xs: Vec<Vec<f64>> =
            (0..7).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();

        let mut blk = ScoreBlock::new(d, xs.len(), KernelMode::Strict);
        let terms = blk.component_terms(&mat, &mean, log_det, &xs, offset, KernelMode::Strict);
        assert_eq!(terms.len(), xs.len());
        let mut e = vec![0.0; d];
        for (bi, x) in xs.iter().enumerate() {
            sub_into(x, &mean, &mut e);
            let expect = log_gaussian(quad_form(&mat, d, &e), log_det, d) + offset;
            assert!(
                terms[bi].to_bits() == expect.to_bits(),
                "strict block term {bi} diverged from per-point sequence"
            );
        }

        let mut fast = ScoreBlock::new(d, xs.len(), KernelMode::Fast);
        let fast_terms =
            fast.component_terms(&mat, &mean, log_det, &xs, offset, KernelMode::Fast);
        let mut w = vec![0.0; d];
        for (bi, x) in xs.iter().enumerate() {
            sub_into(x, &mean, &mut e);
            let q = crate::linalg::packed::quad_form_with_fast(&mat, d, &e, &mut w);
            let expect = log_gaussian(q, log_det, d) + offset;
            assert!(
                fast_terms[bi].to_bits() == expect.to_bits(),
                "fast block term {bi} diverged from per-point fast sequence"
            );
        }
    }
}
