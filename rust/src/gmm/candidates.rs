//! Sublinear top-C candidate search over the flat component arenas.
//!
//! Learn and score are `O(K·D²)` per point because every packed
//! component row is evaluated for every input. Following the candidate-
//! set idea of "Sublinear Variational Optimization of GMMs" (PAPERS.md,
//! arxiv 2501.12299), this module adds a cheap coarse partition over the
//! component *means* — a [`CandidateIndex`] of k-means-style cells held
//! in arenas parallel to [`ComponentStore`] — so the hot surfaces can
//! evaluate only a top-C candidate set per query plus an exact-fallback
//! gate, dropping the per-point cost to `O(√K·D + C·D²)`.
//!
//! ## The two modes ([`SearchMode`])
//!
//! - [`SearchMode::Strict`] (the default) bypasses the index entirely:
//!   every surface runs the existing full-K sweeps, so results are
//!   **bit-identical** to every release before the index existed — the
//!   crate's determinism guarantee is untouched.
//! - [`SearchMode::TopC`] evaluates the C nearest components (by
//!   Euclidean distance of the query to the component means) on the
//!   learn and density surfaces. Accuracy is tolerance-gated, **but the
//!   accept/create decision sequence of `learn` is exactly the full-K
//!   one**: if any candidate passes the χ² novelty test the full sweep
//!   would have accepted too, and when *no* candidate passes, an exact
//!   fallback gate scans the remaining cells — pruning only those whose
//!   Mahalanobis lower bound proves no member can pass — before a
//!   create is allowed. Only the posterior mass assignment (restricted
//!   to the candidate set) is approximate.
//!
//! ## Bounds
//!
//! Each cell keeps its member set, a centroid, a covering `radius`
//! (max Euclidean centroid→member-mean distance, plus accumulated
//! drift `slack` as member means move), and a `lambda_floor`: the
//! minimum Gershgorin lower bound on `λ_min(Λ_j)` over members (zeroed
//! when any member's Λ changes). For a query `x` at Euclidean distance
//! `t` from the centroid, every member mean is at distance
//! `≥ lb = max(0, t − radius − slack)`, hence every member's squared
//! Mahalanobis distance is `≥ lambda_floor·lb²` — a sound (sometimes
//! vacuous, never wrong) bound used to order cells in the top-C scan
//! and to skip whole cells in the exact fallback gate.
//!
//! ## Incremental maintenance
//!
//! The index is no longer rebuilt wholesale on drift. Three incremental
//! paths keep it current under churn (all serial and data-dependent
//! only, so determinism across thread counts is preserved):
//!
//! - **creates** — [`CandidateIndex::note_create`] appends the store's
//!   new last row to its nearest cell, growing that cell's covering
//!   radius (`O(√K·D + D²)`, no rebuild);
//! - **drift** — [`CandidateIndex::note_update`] absorbs small mean
//!   motion into the containing cell's `slack`. Once a component's
//!   accumulated drift exceeds its **per-cell** budget (half the cell's
//!   covering radius; a geometry-derived fallback for degenerate
//!   single-member cells), the component is *reassigned* to the cell
//!   nearest its current mean and every touched cell is refreshed
//!   exactly from the live arenas — centroid, radius, `lambda_floor`
//!   recomputed, `slack` and member drifts reset to zero. Bounds
//!   therefore tighten under sustained drift instead of degrading
//!   until a rebuild;
//! - **escape hatch** — [`CandidateIndex::needs_rebuild`] still forces
//!   the deterministic full [`CandidateIndex::build`] when the row set
//!   changed structurally (generation/K mismatch, e.g. after a prune)
//!   or when more than half the components have migrated cells since
//!   the last build (the coarse partition no longer reflects the data).
//!
//! Every maintenance path preserves bound *soundness* (a cell's bound
//! may be vacuous, never wrong), so `query`'s top-C sets and
//! `scan_possible`'s χ²-reachability scans stay exact regardless of how
//! the current cell structure was reached — an incrementally maintained
//! index and a freshly rebuilt one always return identical candidate
//! sets.
//!
//! The index build is deterministic (serial, input-order dependent
//! only), so TopC results are bit-identical across thread counts and
//! engine attach/detach, and a restored checkpoint rebuilds the
//! identical index from its arenas — the index itself is never
//! serialized.

use super::store::ComponentStore;
use crate::linalg::{packed, sq_dist};

/// How the learn/score surfaces search the component axis. Carried per
/// model (`GmmConfig::search_mode`), serialized with checkpoints,
/// and selectable over the coordinator protocol and the CLI
/// (`train --search-mode topc:64`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SearchMode {
    /// Full-K sweeps on every surface — bit-identical to the pre-index
    /// code paths (the default).
    #[default]
    Strict,
    /// Evaluate only the `c` nearest components per query (plus the
    /// exact-fallback gate on learn). Tolerance-gated accuracy,
    /// `O(C·D²)` per point.
    TopC {
        /// Candidate-set size (≥ 1). `c ≥ K` degenerates to the exact
        /// full-K evaluation.
        c: usize,
    },
}

impl SearchMode {
    /// Wire/CLI encoding: `"strict"` or `"topc:C"` (e.g. `"topc:64"`).
    pub fn to_wire(&self) -> String {
        match self {
            SearchMode::Strict => "strict".to_string(),
            SearchMode::TopC { c } => format!("topc:{c}"),
        }
    }

    /// Parse a wire/CLI name; `None` for anything unknown (including
    /// `topc:0` — an empty candidate set is meaningless).
    pub fn parse(s: &str) -> Option<SearchMode> {
        if s == "strict" {
            return Some(SearchMode::Strict);
        }
        let c = s.strip_prefix("topc:")?.parse::<usize>().ok()?;
        (c >= 1).then_some(SearchMode::TopC { c })
    }

    /// The candidate-set size, `None` in strict mode.
    pub fn top_c(&self) -> Option<usize> {
        match self {
            SearchMode::Strict => None,
            SearchMode::TopC { c } => Some(*c),
        }
    }
}

impl std::fmt::Display for SearchMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_wire())
    }
}

/// Write-path observability for the candidate machinery: how often the
/// index was fully rebuilt vs incrementally maintained, how often the
/// exact χ²-fallback gate had to scan, and how many union rows the
/// masked TopC blocked distance pass streamed. Accumulated per model
/// ([`crate::gmm::IncrementalMixture::index_counters`]) and surfaced
/// through worker/registry stats and the coordinator metrics.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct IndexCounters {
    /// Staleness-triggered full rebuilds on the learn path (structural
    /// row-set mismatch or the mass-migration escape hatch).
    pub rebuilds: u64,
    /// Incremental maintenance events: `note_create` appends plus
    /// per-cell reassignment/refresh rounds.
    pub incremental_updates: u64,
    /// Points whose top-C candidates all failed the χ² test, forcing
    /// the exact fallback-gate cell scan before a create was allowed.
    pub fallback_gate_triggers: u64,
    /// Union rows streamed by the masked TopC blocked distance pass.
    pub masked_block_rows: u64,
}

/// One coarse cell of the quantizer: a centroid over member means with
/// covering and spectral bounds (see the module docs).
#[derive(Debug, Clone)]
struct Cell {
    centroid: Vec<f64>,
    /// Max Euclidean centroid→member-mean distance at build/insert time.
    radius: f64,
    /// Accumulated member mean drift since build (added to `radius` in
    /// every bound, keeping bounds sound without per-update rebuilds).
    slack: f64,
    /// `min_j max(0, Gershgorin λ_min(Λ_j))` over members; zeroed when
    /// any member's Λ is updated, which keeps the Mahalanobis bound
    /// sound (a zero floor is vacuous, never wrong).
    lambda_floor: f64,
    /// Component indices, ascending.
    members: Vec<u32>,
}

/// Coarse quantizer over the component means — see the module docs.
///
/// All operations are serial and depend only on the arena contents, so
/// two stores with equal rows always produce bit-identical indexes
/// (determinism across thread counts and checkpoint round-trips).
#[derive(Debug, Clone)]
pub struct CandidateIndex {
    dim: usize,
    /// Component count the index describes.
    k: usize,
    /// Store generation at build / last structural note.
    generation: u64,
    cells: Vec<Cell>,
    /// Component → cell containing it.
    assign: Vec<u32>,
    /// Per-component accumulated mean drift since build / last refresh
    /// of its cell.
    drift: Vec<f64>,
    /// Drift budget for cells whose own covering radius is degenerate
    /// (single-member cells): derived from the coarse centroid geometry
    /// at build time. Cells with a positive radius budget off that
    /// radius instead — see [`CandidateIndex::cell_budget`].
    fallback_budget: f64,
    /// Components reassigned to a different cell since the last full
    /// build — the escape-hatch trigger in
    /// [`CandidateIndex::needs_rebuild`].
    migrations: usize,
}

impl CandidateIndex {
    /// Build the quantizer over the store's current means: `⌈√K⌉`
    /// stride-seeded cells, one Lloyd refinement sweep, then covering
    /// radii and Gershgorin floors from the packed Λ rows. `O(K·√K·D)`
    /// for assignment plus `O(K·D²)` for the floors — rebuild-time cost
    /// only, amortized over many `O(C·D²)` queries.
    pub fn build(store: &ComponentStore) -> CandidateIndex {
        let k = store.len();
        let d = store.dim();
        assert!(k > 0, "CandidateIndex::build on empty store");
        let n_cells = ((k as f64).sqrt().ceil() as usize).clamp(1, k);

        // Stride-seeded leaders (deterministic spread over arena order).
        let mut centroids: Vec<Vec<f64>> =
            (0..n_cells).map(|i| store.mean(i * k / n_cells).to_vec()).collect();

        // Assign → recompute centroids → assign once more (one Lloyd
        // sweep is enough for a coarse quantizer; more sweeps buy
        // little and cost rebuild latency).
        let mut assign = vec![0u32; k];
        for _sweep in 0..2 {
            for (j, a) in assign.iter_mut().enumerate() {
                *a = nearest_centroid(&centroids, store.mean(j)) as u32;
            }
            let mut counts = vec![0usize; centroids.len()];
            let mut sums = vec![vec![0.0; d]; centroids.len()];
            for (j, &a) in assign.iter().enumerate() {
                counts[a as usize] += 1;
                for (s, &m) in sums[a as usize].iter_mut().zip(store.mean(j)) {
                    *s += m;
                }
            }
            for ((c, s), &n) in centroids.iter_mut().zip(sums.iter()).zip(counts.iter()) {
                if n > 0 {
                    for (ci, &si) in c.iter_mut().zip(s.iter()) {
                        *ci = si / n as f64;
                    }
                }
                // Empty cells keep their seed centroid; they are dropped
                // below after the final assignment.
            }
        }

        // Materialize non-empty cells, preserving centroid order so the
        // construction stays deterministic.
        let mut cells: Vec<Cell> = Vec::with_capacity(centroids.len());
        let mut cell_of_centroid = vec![u32::MAX; centroids.len()];
        for (ci, centroid) in centroids.into_iter().enumerate() {
            let members: Vec<u32> = assign
                .iter()
                .enumerate()
                .filter(|&(_, &a)| a as usize == ci)
                .map(|(j, _)| j as u32)
                .collect();
            if members.is_empty() {
                continue;
            }
            cell_of_centroid[ci] = cells.len() as u32;
            let mut radius = 0.0_f64;
            let mut lambda_floor = f64::INFINITY;
            for &j in &members {
                let j = j as usize;
                radius = radius.max(sq_dist(&centroid, store.mean(j)).sqrt());
                lambda_floor = lambda_floor.min(packed::gershgorin_floor(store.mat(j), d));
            }
            cells.push(Cell { centroid, radius, slack: 0.0, lambda_floor, members });
        }
        for a in assign.iter_mut() {
            *a = cell_of_centroid[*a as usize];
        }

        let fallback_budget = if cells.len() > 1 {
            // Degenerate (single-member) cells have no radius to budget
            // off; use the coarse geometry instead — a quarter of the
            // closest centroid gap.
            let mut min_gap = f64::INFINITY;
            for i in 0..cells.len() {
                for j in i + 1..cells.len() {
                    min_gap = min_gap.min(sq_dist(&cells[i].centroid, &cells[j].centroid));
                }
            }
            0.25 * min_gap.sqrt()
        } else {
            f64::INFINITY // one cell covers everything; drift is harmless
        };

        CandidateIndex {
            dim: d,
            k,
            generation: store.generation(),
            cells,
            assign,
            drift: vec![0.0; k],
            fallback_budget,
            migrations: 0,
        }
    }

    /// Rebuild `slot` in place when it is missing or stale for `store`;
    /// returns whether a (re)build happened.
    pub fn ensure(slot: &mut Option<CandidateIndex>, store: &ComponentStore) -> bool {
        let stale = match slot {
            None => true,
            Some(idx) => idx.needs_rebuild(store),
        };
        if stale && store.len() > 0 {
            *slot = Some(CandidateIndex::build(store));
            return true;
        }
        false
    }

    /// Does the index still describe this store's row set? (Structural
    /// freshness only — drift is tracked separately.)
    pub fn matches(&self, store: &ComponentStore) -> bool {
        self.generation == store.generation() && self.k == store.len()
    }

    /// Structural mismatch, or the incremental-maintenance escape
    /// hatch: more than half the components have migrated cells since
    /// the last full build, so the coarse partition no longer reflects
    /// the data and one deterministic rebuild beats further patching.
    /// Plain drift never triggers a rebuild anymore — it is absorbed
    /// incrementally by [`CandidateIndex::note_update`].
    pub fn needs_rebuild(&self, store: &ComponentStore) -> bool {
        !self.matches(store) || self.migrations * 2 > self.k
    }

    /// Number of coarse cells.
    pub fn num_cells(&self) -> usize {
        self.cells.len()
    }

    /// Cell containing component `j` (test/diagnostic surface).
    pub fn cell_of(&self, j: usize) -> usize {
        self.assign[j] as usize
    }

    /// Components reassigned to a different cell since the last full
    /// build (test/diagnostic surface).
    pub fn migrations(&self) -> usize {
        self.migrations
    }

    /// Accumulated-drift budget of cell `ci`: half its covering radius,
    /// or the build-time geometry fallback when the radius is
    /// degenerate (single-member cell).
    fn cell_budget(&self, ci: usize) -> f64 {
        let r = self.cells[ci].radius;
        if r > 0.0 {
            0.5 * r
        } else {
            self.fallback_budget
        }
    }

    /// The `min(c, K)` components nearest `x` by Euclidean mean
    /// distance, written into `out` in **ascending component order**.
    /// Cells are scanned nearest-bound-first with an early exit once the
    /// next cell's lower bound cannot beat the current C-th best, so
    /// typical cost is `O(√K·D + C·D + |scanned|·D)`. Deterministic:
    /// ties break on the lower component/cell index.
    pub fn query(&self, x: &[f64], c: usize, store: &ComponentStore, out: &mut Vec<u32>) {
        debug_assert!(self.matches(store), "query against a stale index");
        debug_assert_eq!(x.len(), self.dim);
        out.clear();
        let c = c.min(self.k).max(1);

        // Cell scan order: ascending squared Euclidean lower bound.
        let mut order: Vec<(f64, u32)> = self
            .cells
            .iter()
            .enumerate()
            .map(|(ci, cell)| {
                let t = sq_dist(x, &cell.centroid).sqrt();
                let lb = (t - cell.radius - cell.slack).max(0.0);
                (lb * lb, ci as u32)
            })
            .collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

        // Top-C selection, kept sorted ascending by (d², j).
        let mut best: Vec<(f64, u32)> = Vec::with_capacity(c + 1);
        for &(lb2, ci) in &order {
            if best.len() == c && lb2 >= best[c - 1].0 {
                break; // no member of this (or any later) cell can enter
            }
            for &j in &self.cells[ci as usize].members {
                let d2 = sq_dist(x, store.mean(j as usize));
                if best.len() == c && !((d2, j) < best[c - 1]) {
                    continue;
                }
                let pos = best.partition_point(|&(bd, bj)| {
                    bd.total_cmp(&d2).then(bj.cmp(&j)).is_lt()
                });
                best.insert(pos, (d2, j));
                best.truncate(c);
            }
        }
        out.extend(best.iter().map(|&(_, j)| j));
        out.sort_unstable();
    }

    /// The exact-fallback gate's cell scan: visit every component that
    /// could still pass the χ² novelty test and is not already in the
    /// (ascending) `exclude` list. A cell is skipped only when its
    /// Mahalanobis lower bound `lambda_floor·lb²` proves **no** member
    /// can reach `d²_Λ < chi2` — so a create decision after this scan is
    /// exactly the full-K decision.
    pub fn scan_possible(
        &self,
        x: &[f64],
        chi2: f64,
        exclude: &[u32],
        mut visit: impl FnMut(u32),
    ) {
        for cell in &self.cells {
            let t = sq_dist(x, &cell.centroid).sqrt();
            let lb = (t - cell.radius - cell.slack).max(0.0);
            if cell.lambda_floor > 0.0 && cell.lambda_floor * lb * lb >= chi2 {
                continue; // provably out of χ² reach for every member
            }
            for &j in &cell.members {
                if exclude.binary_search(&j).is_err() {
                    visit(j);
                }
            }
        }
    }

    /// Record a freshly pushed component (must be the store's last row):
    /// assign it to the nearest cell, growing that cell's covering
    /// radius and tightening nothing — `O(√K·D + D²)`, no rebuild.
    pub fn note_create(&mut self, store: &ComponentStore) {
        let j = store.len() - 1;
        debug_assert_eq!(self.k, j, "note_create: index missed a row");
        let mean = store.mean(j);
        let ci = self
            .cells
            .iter()
            .enumerate()
            .min_by(|(ai, a), (bi, b)| {
                sq_dist(mean, &a.centroid)
                    .total_cmp(&sq_dist(mean, &b.centroid))
                    .then(ai.cmp(bi))
            })
            .map(|(ci, _)| ci)
            .expect("index has at least one cell");
        let cell = &mut self.cells[ci];
        cell.radius = cell.radius.max(sq_dist(mean, &cell.centroid).sqrt());
        cell.lambda_floor =
            cell.lambda_floor.min(packed::gershgorin_floor(store.mat(j), self.dim));
        cell.members.push(j as u32);
        cell.members.sort_unstable();
        self.assign.push(ci as u32);
        self.drift.push(0.0);
        self.k += 1;
        self.generation = store.generation();
    }

    /// Record an in-place update of component `j` whose mean moved by at
    /// most `shift` (Euclidean): the containing cell's slack absorbs the
    /// motion (bounds stay sound) and its Λ floor is invalidated.
    ///
    /// Incremental maintenance: once `j`'s accumulated drift exceeds
    /// its **per-cell** budget ([`CandidateIndex::cell_budget`]), `j` is
    /// reassigned to the cell nearest its current mean and every
    /// touched cell is refreshed exactly from `store`
    /// ([`CandidateIndex::refresh_cell`]) — so sustained drift tightens
    /// the bounds instead of forcing a full rebuild. Returns the number
    /// of maintenance rounds performed (0 or 1) for the
    /// [`IndexCounters::incremental_updates`] bookkeeping.
    pub fn note_update(&mut self, j: usize, shift: f64, store: &ComponentStore) -> u64 {
        if shift <= 0.0 {
            return 0;
        }
        let ci = self.assign[j] as usize;
        self.cells[ci].slack += shift;
        self.cells[ci].lambda_floor = 0.0;
        self.drift[j] += shift;
        if self.drift[j] <= self.cell_budget(ci) {
            return 0;
        }
        self.reassign(j, store);
        1
    }

    /// Move `j` to the cell nearest its current mean (deterministic:
    /// ties break on the lower cell index), then refresh every touched
    /// cell exactly from the arenas. A reassignment that lands back in
    /// the same cell is a pure refresh and does not count as a
    /// migration.
    fn reassign(&mut self, j: usize, store: &ComponentStore) {
        let old = self.assign[j] as usize;
        let mean = store.mean(j);
        let new = self
            .cells
            .iter()
            .enumerate()
            .min_by(|(ai, a), (bi, b)| {
                sq_dist(mean, &a.centroid)
                    .total_cmp(&sq_dist(mean, &b.centroid))
                    .then(ai.cmp(bi))
            })
            .map(|(ci, _)| ci)
            .expect("index has at least one cell");
        if new != old {
            let members = &mut self.cells[old].members;
            if let Ok(p) = members.binary_search(&(j as u32)) {
                members.remove(p);
            }
            let members = &mut self.cells[new].members;
            let p = members.partition_point(|&m| m < j as u32);
            members.insert(p, j as u32);
            self.assign[j] = new as u32;
            self.migrations += 1;
        }
        self.refresh_cell(old, store);
        if new != old {
            self.refresh_cell(new, store);
        }
    }

    /// Recompute cell `ci` exactly from the live arenas: centroid over
    /// the current member means, covering radius, Gershgorin Λ floor,
    /// `slack = 0`, and member drifts reset — the accumulated motion is
    /// absorbed into exact geometry, so all bounds stay sound *and*
    /// tighten. An emptied cell keeps its (stale) centroid as a future
    /// reassignment target and gets vacuously tight bounds.
    fn refresh_cell(&mut self, ci: usize, store: &ComponentStore) {
        // Split the borrow: `drift` resets happen after the cell borrow
        // ends.
        let d = self.dim;
        let members = std::mem::take(&mut self.cells[ci].members);
        let cell = &mut self.cells[ci];
        if members.is_empty() {
            cell.radius = 0.0;
            cell.slack = 0.0;
            cell.lambda_floor = f64::INFINITY;
            cell.members = members;
            return;
        }
        for c in cell.centroid.iter_mut() {
            *c = 0.0;
        }
        for &j in &members {
            for (c, &m) in cell.centroid.iter_mut().zip(store.mean(j as usize)) {
                *c += m;
            }
        }
        let n = members.len() as f64;
        for c in cell.centroid.iter_mut() {
            *c /= n;
        }
        let mut radius = 0.0_f64;
        let mut lambda_floor = f64::INFINITY;
        for &j in &members {
            let j = j as usize;
            radius = radius.max(sq_dist(&cell.centroid, store.mean(j)).sqrt());
            lambda_floor = lambda_floor.min(packed::gershgorin_floor(store.mat(j), d));
        }
        cell.radius = radius;
        cell.slack = 0.0;
        cell.lambda_floor = lambda_floor;
        cell.members = members;
        for &j in &self.cells[ci].members {
            self.drift[j as usize] = 0.0;
        }
    }
}

fn nearest_centroid(centroids: &[Vec<f64>], x: &[f64]) -> usize {
    let mut best = 0;
    let mut best_d2 = f64::INFINITY;
    for (ci, c) in centroids.iter().enumerate() {
        let d2 = sq_dist(x, c);
        if d2 < best_d2 {
            best_d2 = d2;
            best = ci;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::packed::from_diag;

    fn store_with_means(means: &[&[f64]]) -> ComponentStore {
        let d = means[0].len();
        let mut s = ComponentStore::new(d);
        let lambda = from_diag(&vec![1.0; d]);
        for m in means {
            s.push(m, &lambda, 0.0, 1.0, 1);
        }
        s
    }

    #[test]
    fn wire_format_round_trips() {
        assert_eq!(SearchMode::parse("strict"), Some(SearchMode::Strict));
        assert_eq!(SearchMode::parse("topc:64"), Some(SearchMode::TopC { c: 64 }));
        assert_eq!(SearchMode::parse("topc:0"), None);
        assert_eq!(SearchMode::parse("topc:"), None);
        assert_eq!(SearchMode::parse("topk:4"), None);
        for m in [SearchMode::Strict, SearchMode::TopC { c: 7 }] {
            assert_eq!(SearchMode::parse(&m.to_wire()), Some(m));
            assert_eq!(format!("{m}"), m.to_wire());
        }
        assert_eq!(SearchMode::default(), SearchMode::Strict);
        assert_eq!(SearchMode::TopC { c: 3 }.top_c(), Some(3));
        assert_eq!(SearchMode::Strict.top_c(), None);
    }

    #[test]
    fn query_returns_true_nearest_ascending() {
        // 8 means on a line; nearest-c to any probe is checkable by hand.
        let means: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64 * 10.0, 0.0]).collect();
        let refs: Vec<&[f64]> = means.iter().map(|m| m.as_slice()).collect();
        let store = store_with_means(&refs);
        let idx = CandidateIndex::build(&store);
        assert!(idx.matches(&store));
        let mut out = Vec::new();
        idx.query(&[31.0, 0.0], 3, &store, &mut out);
        assert_eq!(out, vec![2, 3, 4]); // means 20, 30, 40
        // c ≥ K returns everything.
        idx.query(&[31.0, 0.0], 100, &store, &mut out);
        assert_eq!(out, (0..8).collect::<Vec<u32>>());
    }

    #[test]
    fn query_matches_brute_force_on_clustered_means() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seed(11);
        let d = 5;
        let mut s = ComponentStore::new(d);
        let lambda = from_diag(&vec![1.0; d]);
        for g in 0..6 {
            for _ in 0..7 {
                let m: Vec<f64> =
                    (0..d).map(|i| g as f64 * 20.0 + i as f64 + 0.1 * rng.normal()).collect();
                s.push(&m, &lambda, 0.0, 1.0, 1);
            }
        }
        let idx = CandidateIndex::build(&s);
        let mut out = Vec::new();
        for probe in 0..20 {
            let x: Vec<f64> = (0..d).map(|_| 60.0 * rng.uniform()).collect();
            for c in [1, 4, 13] {
                idx.query(&x, c, &s, &mut out);
                // Brute force: sort all (d², j), take c, compare sets.
                let mut all: Vec<(f64, u32)> = (0..s.len())
                    .map(|j| (sq_dist(&x, s.mean(j)), j as u32))
                    .collect();
                all.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                let mut want: Vec<u32> = all[..c].iter().map(|&(_, j)| j).collect();
                want.sort_unstable();
                assert_eq!(out, want, "probe {probe} c {c}");
            }
        }
    }

    #[test]
    fn note_create_tracks_push_without_rebuild() {
        let means: Vec<Vec<f64>> = (0..9).map(|i| vec![i as f64]).collect();
        let refs: Vec<&[f64]> = means.iter().map(|m| m.as_slice()).collect();
        let mut store = store_with_means(&refs);
        let mut idx = CandidateIndex::build(&store);
        store.push(&[4.5], &from_diag(&[1.0]), 0.0, 1.0, 1);
        assert!(!idx.matches(&store));
        idx.note_create(&store);
        assert!(idx.matches(&store));
        assert!(!idx.needs_rebuild(&store));
        let mut out = Vec::new();
        idx.query(&[4.4, ], 2, &store, &mut out);
        assert!(out.contains(&9), "new row must be findable: {out:?}");
    }

    #[test]
    fn drift_triggers_cell_refresh_not_rebuild() {
        let means: Vec<Vec<f64>> = (0..16).map(|i| vec![(i % 4) as f64, (i / 4) as f64]).collect();
        let refs: Vec<&[f64]> = means.iter().map(|m| m.as_slice()).collect();
        let store = store_with_means(&refs);
        let mut idx = CandidateIndex::build(&store);
        assert!(!idx.needs_rebuild(&store));
        // Small drifts accumulate; eventually the per-cell budget trips
        // a reassignment/refresh round — never a full rebuild (the mean
        // itself has not moved, so the refresh absorbs the slack and
        // resets the drift).
        let mut maintained = 0u64;
        for _ in 0..10_000 {
            maintained += idx.note_update(3, 0.05, &store);
            assert!(!idx.needs_rebuild(&store), "drift alone must not force a rebuild");
            if maintained > 0 {
                break;
            }
        }
        assert!(maintained > 0, "accumulated drift never tripped the per-cell budget");
        assert_eq!(idx.migrations(), 0, "a same-cell refresh is not a migration");
        // The refresh reset the drift, so the next small shift does not
        // immediately re-trigger maintenance.
        assert_eq!(idx.note_update(3, 0.05, &store), 0);
        // Bounds stay exact: query still matches brute force.
        let mut out = Vec::new();
        idx.query(&[1.1, 0.9], 4, &store, &mut out);
        let mut all: Vec<(f64, u32)> = (0..store.len())
            .map(|j| (sq_dist(&[1.1, 0.9], store.mean(j)), j as u32))
            .collect();
        all.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut want: Vec<u32> = all[..4].iter().map(|&(_, j)| j).collect();
        want.sort_unstable();
        assert_eq!(out, want);
    }

    #[test]
    fn migrated_component_moves_cell_and_stays_queryable() {
        // Two far clusters → the quantizer puts them in different cells.
        let mut means: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64, 0.0]).collect();
        means.extend((0..8).map(|i| vec![1000.0 + i as f64, 0.0]));
        let refs: Vec<&[f64]> = means.iter().map(|m| m.as_slice()).collect();
        let mut store = store_with_means(&refs);
        let mut idx = CandidateIndex::build(&store);
        let old_cell = idx.cell_of(0);
        // Physically move component 0 into the far cluster, then report
        // the motion. The drift exceeds any per-cell budget, so the
        // component must migrate to a far-cluster cell.
        let shift = {
            let (mean, ..) = store.row_mut(0);
            let from = mean.to_vec();
            mean[0] = 1003.5;
            sq_dist(&from, &[1003.5, 0.0]).sqrt()
        };
        assert_eq!(idx.note_update(0, shift, &store), 1);
        assert_ne!(idx.cell_of(0), old_cell, "component must migrate to the far cluster");
        assert_eq!(idx.migrations(), 1);
        assert!(!idx.needs_rebuild(&store), "one migration is far below the escape hatch");
        // The migrated component is exactly findable at its new home.
        let mut out = Vec::new();
        idx.query(&[1003.4, 0.0], 3, &store, &mut out);
        assert!(out.contains(&0), "migrated row must be findable: {out:?}");
        // Soundness after refresh: brute-force agreement on both ends.
        for probe in [[0.5, 0.0], [1004.0, 0.0]] {
            idx.query(&probe, 5, &store, &mut out);
            let mut all: Vec<(f64, u32)> = (0..store.len())
                .map(|j| (sq_dist(&probe, store.mean(j)), j as u32))
                .collect();
            all.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let mut want: Vec<u32> = all[..5].iter().map(|&(_, j)| j).collect();
            want.sort_unstable();
            assert_eq!(out, want, "probe {probe:?}");
        }
    }

    #[test]
    fn mass_migration_trips_rebuild_escape_hatch() {
        // Two far clusters, 8 components each.
        let mut means: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64, 0.0]).collect();
        means.extend((0..8).map(|i| vec![1000.0 + i as f64, 0.0]));
        let refs: Vec<&[f64]> = means.iter().map(|m| m.as_slice()).collect();
        let mut store = store_with_means(&refs);
        let mut idx = CandidateIndex::build(&store);
        // March most of the near cluster plus some of the far one into
        // fresh territory: more than K/2 migrations must arm the
        // escape hatch.
        let mut tripped = false;
        for j in 0..16 {
            let target = [5000.0 + 10.0 * j as f64, 0.0];
            let shift = {
                let (mean, ..) = store.row_mut(j);
                let from = mean.to_vec();
                mean.copy_from_slice(&target);
                sq_dist(&from, &target).sqrt()
            };
            idx.note_update(j, shift, &store);
            if idx.needs_rebuild(&store) {
                tripped = true;
                break;
            }
        }
        assert!(tripped, "mass migration never tripped the rebuild escape hatch");
        assert!(idx.migrations() * 2 > store.len());
    }

    #[test]
    fn scan_possible_visits_all_reachable_members() {
        // Identity Λ on every component → lambda_floor = 1, so a cell
        // at Euclidean lower bound lb is prunable iff lb² ≥ chi2.
        let means: Vec<Vec<f64>> = (0..12).map(|i| vec![i as f64 * 100.0]).collect();
        let refs: Vec<&[f64]> = means.iter().map(|m| m.as_slice()).collect();
        let store = store_with_means(&refs);
        let idx = CandidateIndex::build(&store);
        let x = [0.0];
        let chi2 = 25.0; // only component 0 (distance 0) can pass
        let mut visited = Vec::new();
        idx.scan_possible(&x, chi2, &[], |j| visited.push(j));
        visited.sort_unstable();
        assert!(visited.contains(&0));
        // Soundness: every component with d²_Λ < chi2 must be visited.
        for j in 0..store.len() {
            if sq_dist(&x, store.mean(j)) < chi2 {
                assert!(visited.contains(&(j as u32)), "missed reachable component {j}");
            }
        }
        // Exclusion list suppresses already-evaluated candidates.
        let mut without0 = Vec::new();
        idx.scan_possible(&x, chi2, &[0], |j| without0.push(j));
        assert!(!without0.contains(&0));
        // After an update invalidates a cell's floor, its members are
        // always visited (vacuous bound).
        let mut idx2 = idx.clone();
        let far = (store.len() - 1) as u32;
        idx2.note_update(far as usize, 0.01, &store);
        let mut v2 = Vec::new();
        idx2.scan_possible(&x, chi2, &[], |j| v2.push(j));
        assert!(v2.contains(&far), "zeroed floor must make the cell unprunable");
    }

    #[test]
    fn build_is_deterministic() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seed(3);
        let d = 3;
        let mut s = ComponentStore::new(d);
        let lambda = from_diag(&vec![2.0; d]);
        for _ in 0..40 {
            let m: Vec<f64> = (0..d).map(|_| 10.0 * rng.normal()).collect();
            s.push(&m, &lambda, 0.0, 1.0, 1);
        }
        let a = CandidateIndex::build(&s);
        let b = CandidateIndex::build(&s);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.num_cells(), b.num_cells());
        let mut oa = Vec::new();
        let mut ob = Vec::new();
        for probe in 0..10 {
            let x: Vec<f64> = (0..d).map(|_| 10.0 * rng.normal()).collect();
            a.query(&x, 5, &s, &mut oa);
            b.query(&x, 5, &s, &mut ob);
            assert_eq!(oa, ob, "probe {probe}");
        }
    }
}
