//! The paper's algorithms.
//!
//! Two complete implementations of the Incremental Gaussian Mixture
//! Network share one set of semantics (identical create/update/prune
//! decisions, identical predictions — the paper's Section 4 equivalence
//! claim, enforced by this crate's property tests):
//!
//! - [`Igmn`] — the **original** covariance-matrix formulation (paper
//!   §2): per point it factorizes each component's covariance to get the
//!   Mahalanobis distance and determinant — `O(KD³)` per point.
//! - [`Figmn`] — the **fast** precision-matrix formulation (paper §3):
//!   Sherman–Morrison rank-one updates of `Λ = C⁻¹` and
//!   Matrix-Determinant-Lemma updates of `log|C|` — `O(KD²)` per point.
//!
//! Both implement [`IncrementalMixture`], which the evaluation harness,
//! the coordinator workers, and the benchmarks are generic over.
//!
//! ## Component storage: flat SoA arenas
//!
//! Both variants keep all mixture state in a [`ComponentStore`] — flat
//! contiguous arenas (a `K×D` mean block, a `K×D(D+1)/2` block of
//! **packed upper-triangular symmetric** matrices, and parallel
//! `log_det`/`sp`/`v` arrays) instead of K per-component heap objects.
//! The paper's two hot kernels (the `Λ·v` product of Eq. 22 and the
//! fused Sherman–Morrison update of Eqs. 20–21/25–26) are
//! memory-bandwidth-bound at scale, and the packed layout halves the
//! bytes each sweep moves while streaming components contiguously.
//!
//! **Packed-symmetric invariant:** the update rules keep every
//! component matrix *exactly* symmetric in floating point (the
//! `α·(uᵢ·uⱼ)` trick in `linalg::rank_one`), so the upper triangle is
//! the whole matrix, and the packed kernels in [`crate::linalg::packed`]
//! perform the same floating-point operations in the same order as
//! their dense counterparts. Every density, posterior, prediction and
//! learn trajectory is therefore **bit-identical** to the dense
//! formulation — see `tests/layout_equivalence.rs`, which replays a
//! dense array-of-structs reference implementation side by side.
//!
//! Component lifecycle is arena row manipulation: create appends a row,
//! the §2.3 prune compacts rows in place (order-preserving, so the
//! deterministic tree merges see the same component order regardless of
//! layout), and snapshot publishing bulk-copies the arenas. The arenas
//! are **capacity-reserved** from `GmmConfig::max_components` (and grow
//! geometrically in lock-step otherwise), so a mid-stream create never
//! moves the hot rows under the engine's raw row views.
//!
//! ## Kernel modes: when bit-identity holds
//!
//! Each model carries a [`KernelMode`] (`GmmConfig::kernel_mode`):
//!
//! - **`Strict`** (default): every density, posterior, prediction and
//!   learn trajectory is bit-identical to the dense formulation, across
//!   layouts, thread counts, checkpoint round-trips, and snapshots.
//! - **`Fast`**: the precision path's distance/score sweeps and fused
//!   update run blocked SIMD-friendly kernels. Results are
//!   tolerance-equivalent to `Strict` (relative ~1e-12 on
//!   log-densities; `tests/kernel_mode_equivalence.rs`) and still
//!   bit-deterministic across thread counts *within* the mode.
//!   Conditional inference (`predict`) and the `Igmn` baseline always
//!   run strict kernels.
//!
//! The mode round-trips through checkpoints (v2 `kernel_mode` field;
//! older readers that ignore the field still load the document and
//! score within tolerance) and is selectable per model over the
//! coordinator protocol and the CLI.
//!
//! ## Read replicas: when the serving path goes f32
//!
//! Each model also carries a [`ReplicaMode`] (`GmmConfig::replica_mode`,
//! default `Off`): with `F32 { tol }`, every published [`ModelSnapshot`]
//! additionally materializes a [`ReplicaStore`] — f32 copies of the
//! mean and packed-matrix arenas — and serves the density surfaces
//! from it through the f32 multi-query kernels, halving bytes streamed
//! per scoring sweep. The replica exists *only* on immutable published
//! snapshots: the write path, conditional inference, and every `Strict`
//! bit-identity contract stay f64 (see [`replica`] for the tolerance
//! contract). Like the kernel mode, it round-trips through checkpoint
//! v2 (additive `replica_mode` field), the protocol, and the CLI.
//!
//! ## Learn modes: when the write path stages blocks
//!
//! Each model carries a [`LearnMode`] (`GmmConfig::learn_mode`, default
//! `Online`): with `MiniBatch { b }`, `learn_batch` stages `b`-point
//! blocks through the staged pipeline of [`learn_pipeline`] — one
//! blocked `K×B` distance pass per block (the PR 5 tiling, now on the
//! write path), sequential per-point novelty decisions against the
//! frozen block scores, then a component-outer fused-update stage that
//! streams each packed row once per block. `Online` (and
//! `MiniBatch { b: 1 }`, and blocks of length 1) is bit-identical to
//! the pre-pipeline learn path at every thread count; larger blocks
//! are the classical mini-batch approximation, still bit-deterministic
//! across thread counts. Two drift-adaptive knobs ride along —
//! `GmmConfig::decay` (per-point exponential `sp` forgetting) and
//! `GmmConfig::max_age` (argmax-winner age eviction through the §2.3
//! sweep) — both default off with zero floating-point cost. All three
//! round-trip through checkpoint v2 (additive `learn_mode` /
//! `decay` / `max_age` fields), the protocol, and the CLI.
//!
//! [`SupervisedGmm`] layers the paper's "any element predicts any other
//! element" autoassociative trick into a conventional classifier
//! interface (features + one-hot class concatenated into the joint input
//! vector; class scores reconstructed at query time via Eq. 15/27).

pub mod candidates;
mod config;
mod figmn;
mod igmn;
pub mod inference;
pub mod learn_pipeline;
pub mod replica;
mod score_block;
mod serialize;
mod snapshot;
mod store;
pub mod supervised;

pub use candidates::{CandidateIndex, IndexCounters, SearchMode};
pub use config::GmmConfig;
pub use figmn::Figmn;
pub use igmn::Igmn;
pub use learn_pipeline::LearnMode;
pub use replica::{ReplicaMode, ReplicaStore, DEFAULT_F32_TOL};
pub use serialize::{CHECKPOINT_MIN_VERSION, CHECKPOINT_VERSION};
pub use snapshot::ModelSnapshot;
pub use store::{ComponentStore, MatKind};
pub use supervised::SupervisedGmm;

// The per-model kernel-mode selector lives in `linalg` (it gates the
// packed kernels) but is configured here (`GmmConfig::kernel_mode`), so
// re-export it where model builders look for it.
pub use crate::linalg::KernelMode;

/// Outcome of presenting one data point to the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LearnOutcome {
    /// An existing component won the χ² test and the mixture was updated.
    Updated,
    /// No component accepted the point; a new one was created.
    Created,
}

/// Common interface of both IGMN variants (and of remote/XLA-backed
/// models in the coordinator).
///
/// The `*_batch` methods are the engine-facing surface: the provided
/// defaults loop over the serial entry points, and the two native
/// implementations override the scoring ones to amortize their
/// component-sharded thread pool across the whole batch (see
/// [`crate::engine`]). Batch results are always identical to the serial
/// loop — batching changes scheduling, never semantics.
pub trait IncrementalMixture {
    /// Present one joint data vector (paper Algorithm 1 body).
    fn learn(&mut self, x: &[f64]) -> LearnOutcome;

    /// Number of live Gaussian components.
    fn num_components(&self) -> usize;

    /// Joint input dimensionality `D`.
    fn dim(&self) -> usize;

    /// Reconstruct the `target_idx` elements given values for the
    /// `known_idx` elements (paper Eq. 15 / Eq. 27).
    fn predict(&self, known_vals: &[f64], known_idx: &[usize], target_idx: &[usize]) -> Vec<f64>;

    /// Joint log-density `ln p(x)` under the mixture.
    fn log_density(&self, x: &[f64]) -> f64;

    /// Posterior responsibilities `p(j|x)` for a full joint vector.
    fn posteriors(&self, x: &[f64]) -> Vec<f64>;

    /// Total points presented.
    fn points_seen(&self) -> u64;

    /// Candidate-index observability counters (rebuilds, incremental
    /// maintenance events, fallback-gate scans, masked block rows).
    /// Models without a candidate index report all-zero.
    fn index_counters(&self) -> IndexCounters {
        IndexCounters::default()
    }

    /// Present a batch of joint vectors in stream order. Learning is
    /// sequential in the stream (each point scores against the state the
    /// previous point produced), so this is exactly the serial loop —
    /// implementations may still shard the per-point component work.
    fn learn_batch(&mut self, xs: &[Vec<f64>]) -> Vec<LearnOutcome> {
        xs.iter().map(|x| self.learn(x)).collect()
    }

    /// Joint log-densities `ln p(x)` for a batch of points.
    fn score_batch(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.log_density(x)).collect()
    }

    /// Conditional reconstructions for a batch of points sharing one
    /// known/target index split (paper Eq. 15 / Eq. 27 per point).
    fn predict_batch(
        &self,
        known_vals: &[Vec<f64>],
        known_idx: &[usize],
        target_idx: &[usize],
    ) -> Vec<Vec<f64>> {
        known_vals.iter().map(|x| self.predict(x, known_idx, target_idx)).collect()
    }
}

/// Shared log-space posterior computation: given per-component
/// `ln p(x|j)` and unnormalized priors (sp), return normalized `p(j|x)`.
/// Uses the max-shift trick so D=3072 log-likelihoods don't underflow.
///
/// The normalizer is a deterministic pairwise [`crate::engine::tree_sum`]
/// whose reduction shape depends only on K — the "merge posteriors"
/// step the engine's determinism guarantee rests on (serial and sharded
/// execution both funnel per-component scores through this one
/// function, so they agree bit-for-bit).
pub(crate) fn softmax_posteriors(log_liks: &[f64], sps: &[f64]) -> Vec<f64> {
    debug_assert_eq!(log_liks.len(), sps.len());
    let mut best = f64::NEG_INFINITY;
    let mut scores = Vec::with_capacity(log_liks.len());
    for (&ll, &sp) in log_liks.iter().zip(sps.iter()) {
        // ln(p(x|j)·p(j)) up to the shared ln Σsp constant.
        let s = ll + sp.max(1e-300).ln();
        scores.push(s);
        if s > best {
            best = s;
        }
    }
    if !best.is_finite() {
        // All components at −∞ (or no components): uniform fallback.
        let k = log_liks.len().max(1);
        return vec![1.0 / k as f64; log_liks.len()];
    }
    for s in &mut scores {
        *s = (*s - best).exp();
    }
    let total = crate::engine::tree_sum(&scores);
    for s in &mut scores {
        *s /= total;
    }
    scores
}

/// `ln N(x; μ, C)` from a precomputed squared Mahalanobis distance and
/// `log|C|` (paper Eq. 2 in log space).
#[inline]
pub(crate) fn log_gaussian(d2: f64, log_det: f64, dim: usize) -> f64 {
    -0.5 * (dim as f64) * (2.0 * std::f64::consts::PI).ln() - 0.5 * log_det - 0.5 * d2
}

/// The supervised joint-vector convention, in one place: the leading
/// `n_features` joint dims are features, the trailing `n_classes` the
/// one-hot (or regression-target) block. Shared by `SupervisedGmm` and
/// `ModelSnapshot` so the two can never disagree about which dims are
/// targets.
pub(crate) fn index_split(n_features: usize, n_classes: usize) -> (Vec<usize>, Vec<usize>) {
    (
        (0..n_features).collect(),
        (n_features..n_features + n_classes).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_posteriors_normalized() {
        let p = softmax_posteriors(&[-1000.0, -1001.0, -999.0], &[1.0, 2.0, 3.0]);
        let sum: f64 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!(p[2] > p[0]);
    }

    #[test]
    fn softmax_handles_degenerate() {
        let p = softmax_posteriors(&[f64::NEG_INFINITY, f64::NEG_INFINITY], &[1.0, 1.0]);
        assert_eq!(p, vec![0.5, 0.5]);
    }

    #[test]
    fn log_gaussian_standard_normal_at_zero() {
        // ln N(0; 0, 1) = −½ln(2π)
        let v = log_gaussian(0.0, 0.0, 1);
        assert!((v + 0.5 * (2.0 * std::f64::consts::PI).ln()).abs() < 1e-15);
    }
}
