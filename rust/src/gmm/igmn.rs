//! IGMN — the original covariance-matrix formulation (paper §2).
//!
//! This is the paper's *baseline*: semantically identical to [`Figmn`]
//! but paying `O(D³)` per point per component — each Mahalanobis
//! distance/likelihood needs a fresh factorization of `C_j` (Eq. 1–2),
//! while the covariance update itself (Eq. 11) is `O(D²)`.
//!
//! Implementation notes: component state lives in the same flat
//! [`super::ComponentStore`] arenas as the fast path (the matrices here
//! are packed covariances `C`; the `log_det` arena stays unused —
//! determinants come from each factorization). The factorization is a
//! Cholesky (numerically kinder than the explicit inverse the paper's
//! Weka code computes, same asymptotic cost, same results), run
//! directly on the packed row via [`Cholesky::new_packed`]; likelihoods
//! are evaluated in log space exactly like the fast path so the two
//! implementations produce the same numbers — the property the paper
//! verifies in Section 4.

use super::inference::covariance_conditional;
use super::store::ComponentStore;
use super::{log_gaussian, softmax_posteriors, GmmConfig, IncrementalMixture, LearnOutcome};
use crate::engine::{
    logsumexp_tree, worth_sharding, worth_sharding_work, EngineConfig, SharedMut, WorkerPool,
};
use crate::linalg::{packed, sub_into, Cholesky, Matrix};

/// The original IGMN (paper §2) — the `O(NKD³)` baseline.
pub struct Igmn {
    cfg: GmmConfig,
    sigma_ini: Vec<f64>,
    /// Component arenas; matrices are packed covariances `C`.
    store: ComponentStore,
    points: u64,
    /// Optional component-sharded thread pool (None = serial). The
    /// per-component Cholesky factorizations (the O(KD³) cost the paper
    /// attacks) shard across it exactly like `Figmn`'s passes.
    engine: Option<WorkerPool>,
    buf_e: Vec<f64>,
    buf_dmu: Vec<f64>,
}

impl Igmn {
    pub fn new(cfg: GmmConfig, dataset_stds: &[f64]) -> Self {
        let sigma_ini = cfg.sigma_ini(dataset_stds);
        let d = cfg.dim;
        // Covariance-variant store (the log_det lane is unused here, so
        // byte accounting skips it), reserved up front when the
        // component count is bounded — same budget-clamped
        // no-mid-stream-reallocation contract as the fast path.
        let store = if cfg.max_components > 0 {
            ComponentStore::with_capacity_covariance(
                d,
                ComponentStore::bounded_reservation_rows(d, cfg.max_components),
            )
        } else {
            ComponentStore::new_covariance(d)
        };
        Igmn {
            cfg,
            sigma_ini,
            store,
            points: 0,
            engine: None,
            buf_e: vec![0.0; d],
            buf_dmu: vec![0.0; d],
        }
    }

    pub fn config(&self) -> &GmmConfig {
        &self.cfg
    }

    /// Per-dimension `σ_ini` (Eq. 13) this model was built with.
    pub fn sigma_ini(&self) -> &[f64] {
        &self.sigma_ini
    }

    /// Reassemble a model from restored state (checkpoint loading).
    pub(crate) fn from_parts(
        cfg: GmmConfig,
        sigma_ini: Vec<f64>,
        mut store: ComponentStore,
        points: u64,
    ) -> Self {
        let d = cfg.dim;
        assert_eq!(store.dim(), d, "from_parts: store dim mismatch");
        let target = ComponentStore::bounded_reservation_rows(d, cfg.max_components);
        if target > store.len() {
            store.reserve(target - store.len());
        }
        Igmn {
            cfg,
            sigma_ini,
            store,
            points,
            engine: None,
            buf_e: vec![0.0; d],
            buf_dmu: vec![0.0; d],
        }
    }

    /// Attach a component-sharded execution engine (bit-identical
    /// results for every thread count; see [`crate::engine`]).
    pub fn with_engine(mut self, cfg: EngineConfig) -> Self {
        self.set_engine(Some(cfg));
        self
    }

    /// Attach (`Some`) or detach (`None`) the engine at runtime.
    pub fn set_engine(&mut self, cfg: Option<EngineConfig>) {
        self.engine = cfg.map(|c| WorkerPool::new(c.resolve_threads()));
    }

    /// Worker threads backing this model (1 when no engine is attached).
    pub fn engine_threads(&self) -> usize {
        self.engine.as_ref().map_or(1, |p| p.threads())
    }

    /// The flat component arenas backing this model.
    pub fn store(&self) -> &ComponentStore {
        &self.store
    }

    /// Mean of component `j`.
    pub fn component_mean(&self, j: usize) -> &[f64] {
        self.store.mean(j)
    }

    /// Covariance of component `j`, expanded to dense form (the arenas
    /// store it packed).
    pub fn component_cov(&self, j: usize) -> Matrix {
        self.store.mat_dense(j)
    }

    /// `(sp_j, v_j)`.
    pub fn component_stats(&self, j: usize) -> (f64, u64) {
        (self.store.sp(j), self.store.v(j))
    }

    /// Arena bytes per component (packed layout).
    pub fn bytes_per_component(&self) -> usize {
        self.store.bytes_per_component()
    }

    /// Total arena payload of the live mixture.
    pub fn model_bytes(&self) -> usize {
        self.store.model_bytes()
    }

    fn create(&mut self, x: &[f64]) {
        let s2: Vec<f64> = self.sigma_ini.iter().map(|&s| s * s).collect();
        let cov = packed::from_diag(&s2);
        self.store.push(x, &cov, 0.0, 1.0, 1);
    }

    /// Distances + log-dets for all components — `O(KD³)`: one Cholesky
    /// per component per point. This cost is the paper's whole point,
    /// and the engine's best case: each factorization shards
    /// independently across the pool.
    fn score(&self, x: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let k = self.store.len();
        let d = self.cfg.dim;
        let mut d2s = vec![0.0; k];
        let mut log_dets = vec![0.0; k];
        // Gate on the real per-component cost: the Cholesky here is
        // O(D³), not the O(D²) the precision-path gate assumes.
        match &self.engine {
            Some(pool) if worth_sharding_work(k, d * d * d, pool.threads()) => {
                let store = &self.store;
                let d2p = SharedMut::new(d2s.as_mut_ptr());
                let ldp = SharedMut::new(log_dets.as_mut_ptr());
                pool.run(k, &move |_, range, scratch| {
                    scratch.ensure(d);
                    for j in range {
                        let e = &mut scratch.e[..d];
                        sub_into(x, store.mean(j), e);
                        let chol = Cholesky::new_packed(store.mat(j), d)
                            .expect("covariance must stay PD");
                        // Safety: slot j is owned by exactly one shard.
                        unsafe {
                            *d2p.at(j) = chol.quad_form_inv(e);
                            *ldp.at(j) = chol.log_det();
                        }
                    }
                });
            }
            _ => {
                let mut e = vec![0.0; d];
                for j in 0..k {
                    sub_into(x, self.store.mean(j), &mut e);
                    let chol = Cholesky::new_packed(self.store.mat(j), d)
                        .expect("covariance must stay PD");
                    d2s[j] = chol.quad_form_inv(&e);
                    log_dets[j] = chol.log_det();
                }
            }
        }
        (d2s, log_dets)
    }

    fn update_all(&mut self, x: &[f64], d2s: &[f64], log_dets: &[f64]) {
        let dim = self.cfg.dim;
        let k = self.store.len();
        let mut lls = Vec::with_capacity(k);
        for (&d2, &ld) in d2s.iter().zip(log_dets.iter()) {
            lls.push(log_gaussian(d2, ld, dim));
        }
        let post = softmax_posteriors(&lls, self.store.sps());
        let Igmn { store, engine, buf_e, buf_dmu, .. } = self;
        match engine.as_ref() {
            Some(pool) if worth_sharding(k, dim, pool.threads()) => {
                let raw = store.raw_mut();
                let post = &post[..];
                pool.run(k, &move |_, range, scratch| {
                    scratch.ensure(dim);
                    for j in range {
                        // Safety: arena row j is owned by exactly one
                        // shard.
                        let (mean, cov, _, sp, v) = unsafe { raw.row_mut(j) };
                        update_cov_component(
                            mean,
                            cov,
                            sp,
                            v,
                            x,
                            dim,
                            post[j],
                            &mut scratch.e[..dim],
                            &mut scratch.tmp[..dim],
                        );
                    }
                });
            }
            _ => {
                for j in 0..k {
                    let (mean, cov, _, sp, v) = store.row_mut(j);
                    update_cov_component(
                        mean,
                        cov,
                        sp,
                        v,
                        x,
                        dim,
                        post[j],
                        &mut buf_e[..dim],
                        &mut buf_dmu[..dim],
                    );
                }
            }
        }
    }

    fn prune(&mut self) {
        if !self.cfg.prune {
            return;
        }
        // Same sweep as Figmn::prune (the store's shared compaction):
        // identical prune decisions, and the mixture never empties.
        self.store.prune(self.cfg.v_min, self.cfg.sp_min);
    }
}

/// Component-local body of the covariance update (Eqs. 4–11), shared by
/// the serial and sharded paths — one instruction sequence, so the two
/// are bit-identical.
#[allow(clippy::too_many_arguments)]
fn update_cov_component(
    mean: &mut [f64],
    cov: &mut [f64],
    sp: &mut f64,
    v: &mut u64,
    x: &[f64],
    d: usize,
    p: f64,
    e: &mut [f64],
    dmu: &mut [f64],
) {
    *v += 1; // Eq. 4
    *sp += p; // Eq. 5
    let omega = p / *sp; // Eq. 7
    if omega <= 0.0 {
        return; // Eqs. 8–11 are exact no-ops when ω underflows
    }
    sub_into(x, mean, e); // Eq. 6
    for ((m, &ei), di) in mean.iter_mut().zip(e.iter()).zip(dmu.iter_mut()) {
        *di = omega * ei; // Eq. 8
        *m += *di; // Eq. 9
    }
    // Eq. 11, exact form: C ← (1−ω)C + ω·e·eᵀ − Δμ·Δμᵀ with the
    // OLD-mean error e (Engel & Heinen 2010). The FIGMN paper prints e*
    // (the new-mean error) here; that variant is not the exact
    // weighted-covariance recurrence and loses positive definiteness at
    // ω = ½ (a component's second point) for D ≥ 2. Both forms cost the
    // same; see DESIGN.md §Deviations.
    packed::scale(cov, 1.0 - omega);
    packed::syr_packed(cov, d, omega, e);
    packed::syr_packed(cov, d, -1.0, dmu);
}

impl IncrementalMixture for Igmn {
    fn learn(&mut self, x: &[f64]) -> LearnOutcome {
        assert_eq!(x.len(), self.cfg.dim, "learn: dimensionality mismatch");
        self.points += 1;
        if self.store.is_empty() {
            self.create(x);
            return LearnOutcome::Created;
        }
        let (d2s, log_dets) = self.score(x);
        let accept = d2s.iter().any(|&d2| d2 < self.cfg.chi2_threshold());
        let cap_full =
            self.cfg.max_components > 0 && self.store.len() >= self.cfg.max_components;
        if accept || cap_full {
            self.update_all(x, &d2s, &log_dets);
            self.prune();
            LearnOutcome::Updated
        } else {
            self.create(x);
            self.prune();
            LearnOutcome::Created
        }
    }

    fn num_components(&self) -> usize {
        self.store.len()
    }

    fn dim(&self) -> usize {
        self.cfg.dim
    }

    fn predict(&self, known_vals: &[f64], known_idx: &[usize], target_idx: &[usize]) -> Vec<f64> {
        assert_eq!(known_vals.len(), known_idx.len());
        assert!(!self.store.is_empty(), "predict on empty model");
        let k = self.store.len();
        let d = self.cfg.dim;
        let mut log_liks = Vec::with_capacity(k);
        let mut recons = Vec::with_capacity(k);
        for j in 0..k {
            let r = covariance_conditional(
                self.store.mat(j),
                d,
                self.store.mean(j),
                known_vals,
                known_idx,
                target_idx,
            );
            log_liks.push(r.log_lik);
            recons.push(r.reconstruction);
        }
        let post = softmax_posteriors(&log_liks, self.store.sps()); // Eq. 14
        let mut out = vec![0.0; target_idx.len()];
        for (p, r) in post.iter().zip(recons.iter()) {
            for (o, &v) in out.iter_mut().zip(r.iter()) {
                *o += p * v; // Eq. 15 mixture
            }
        }
        out
    }

    fn log_density(&self, x: &[f64]) -> f64 {
        assert!(!self.store.is_empty());
        let total_sp = self.store.total_sp();
        let (d2s, lds) = self.score(x);
        // Same deterministic tree merge as the fast variant, so the two
        // implementations produce the same numbers (paper §4).
        let terms: Vec<f64> = self
            .store
            .sps()
            .iter()
            .zip(d2s.iter())
            .zip(lds.iter())
            .map(|((&sp, &d2), &ld)| log_gaussian(d2, ld, self.cfg.dim) + (sp / total_sp).ln())
            .collect();
        logsumexp_tree(&terms)
    }

    fn posteriors(&self, x: &[f64]) -> Vec<f64> {
        let (d2s, lds) = self.score(x);
        let lls: Vec<f64> = d2s
            .iter()
            .zip(lds.iter())
            .map(|(&d2, &ld)| log_gaussian(d2, ld, self.cfg.dim))
            .collect();
        softmax_posteriors(&lls, self.store.sps())
    }

    fn points_seen(&self) -> u64 {
        self.points
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmm::Figmn;
    use crate::rng::Pcg64;
    use crate::testutil::{assert_close, assert_rel, check};

    /// THE paper's Section-4 equivalence claim: original and fast IGMN,
    /// fed the same stream with the same hyper-parameters, produce the
    /// same components, the same predictions, and the same densities.
    #[test]
    fn igmn_equals_figmn_on_random_streams() {
        check(15, |rng| {
            let d = 2 + rng.below(5);
            let n_clusters = 1 + rng.below(3);
            let cfg = GmmConfig::new(d).with_delta(0.3 + rng.uniform()).with_beta(0.05);
            let stds = vec![2.0; d];
            let mut slow = Igmn::new(cfg.clone(), &stds);
            let mut fast = Figmn::new(cfg, &stds);

            let centers: Vec<Vec<f64>> =
                (0..n_clusters).map(|_| (0..d).map(|_| rng.normal() * 8.0).collect()).collect();
            for step in 0..120 {
                let c = &centers[step % n_clusters];
                let x: Vec<f64> = c.iter().map(|&m| m + rng.normal() * 0.8).collect();
                let a = slow.learn(&x);
                let b = fast.learn(&x);
                assert_eq!(a, b, "create/update decisions diverged at step {step}");
            }
            assert_eq!(slow.num_components(), fast.num_components());

            // Components match.
            for j in 0..fast.num_components() {
                assert_close(slow.component_mean(j), fast.component_mean(j), 1e-6);
                let (sp_a, v_a) = slow.component_stats(j);
                let (sp_b, v_b) = fast.component_stats(j);
                assert_rel(sp_a, sp_b, 1e-6);
                assert_eq!(v_a, v_b);
                // Λ ≡ C⁻¹.
                let c_inv = slow.component_cov(j).inverse().unwrap();
                let lam = fast.component_lambda(j);
                assert!(
                    c_inv.max_abs_diff(&lam)
                        < 1e-5 * (1.0 + c_inv.as_slice().iter().fold(0.0f64, |m, v| m.max(v.abs()))),
                    "Λ vs C⁻¹ diverged for component {j}"
                );
            }

            // Predictions and densities match.
            let mut probe = Pcg64::seed(rng.next_u64());
            for _ in 0..10 {
                let x: Vec<f64> = (0..d).map(|_| probe.normal() * 5.0).collect();
                assert_rel(slow.log_density(&x), fast.log_density(&x), 1e-6);
                assert_close(&slow.posteriors(&x), &fast.posteriors(&x), 1e-6);
                let known: Vec<usize> = (0..d - 1).collect();
                let pa = slow.predict(&x[..d - 1], &known, &[d - 1]);
                let pb = fast.predict(&x[..d - 1], &known, &[d - 1]);
                assert_close(&pa, &pb, 1e-6);
            }
        });
    }

    /// The §4 equivalence must hold *through pruning*: with aggressive
    /// prune thresholds on random streams, both variants make identical
    /// create/update/prune decisions at every step (same K after every
    /// point), pruning actually fires, and neither mixture ever empties.
    #[test]
    fn igmn_equals_figmn_with_pruning_enabled() {
        check(12, |rng| {
            let d = 2 + rng.below(3);
            let cfg = GmmConfig::new(d)
                .with_delta(0.2 + 0.5 * rng.uniform())
                .with_beta(0.2)
                .with_pruning(2 + rng.below(3) as u64, 1.5 + rng.uniform());
            let stds = vec![2.0; d];
            let mut slow = Igmn::new(cfg.clone(), &stds);
            let mut fast = Figmn::new(cfg, &stds);

            let n_clusters = 2 + rng.below(3);
            let centers: Vec<Vec<f64>> = (0..n_clusters)
                .map(|_| (0..d).map(|_| rng.normal() * 10.0).collect())
                .collect();
            let mut max_k = 0usize;
            let mut pruned_total = 0usize;
            for step in 0..150 {
                // Mostly clustered points with occasional far outliers so
                // spurious components appear and get pruned.
                let x: Vec<f64> = if step % 11 == 10 {
                    (0..d).map(|_| rng.normal() * 60.0).collect()
                } else {
                    centers[step % n_clusters]
                        .iter()
                        .map(|&m| m + rng.normal() * 0.8)
                        .collect()
                };
                let before = fast.num_components();
                let a = slow.learn(&x);
                let b = fast.learn(&x);
                assert_eq!(a, b, "create/update diverged at step {step}");
                assert_eq!(
                    slow.num_components(),
                    fast.num_components(),
                    "prune decisions diverged at step {step}"
                );
                assert!(fast.num_components() >= 1, "mixture emptied at step {step}");
                max_k = max_k.max(fast.num_components());
                // K before prune = before (+1 on a create step).
                let base = before + usize::from(b == LearnOutcome::Created);
                pruned_total += base - fast.num_components();
            }
            assert!(pruned_total > 0 || max_k == 1, "pruning never fired (max K = {max_k})");

            // Surviving components still match across variants.
            for j in 0..fast.num_components() {
                assert_close(slow.component_mean(j), fast.component_mean(j), 1e-5);
                let (sp_a, v_a) = slow.component_stats(j);
                let (sp_b, v_b) = fast.component_stats(j);
                assert_rel(sp_a, sp_b, 1e-5);
                assert_eq!(v_a, v_b);
            }
        });
    }

    #[test]
    fn prune_never_empties_the_mixture() {
        // Same regression stream as the Figmn test: after one accepted
        // point every component trips the spuriousness predicate at
        // once; the strongest must survive.
        let cfg = GmmConfig::new(1).with_delta(1.0).with_beta(0.9).with_pruning(1, 100.0);
        let mut m = Igmn::new(cfg, &[1.0]);
        m.learn(&[0.0]);
        m.learn(&[1000.0]);
        assert_eq!(m.num_components(), 2);
        m.learn(&[0.0]);
        assert_eq!(m.num_components(), 1, "strongest component must survive");
        assert!(m.component_mean(0)[0].abs() < 1.0);
        assert!(m.log_density(&[0.0]).is_finite());
        assert!(m.posteriors(&[0.0]) == vec![1.0]);
    }

    #[test]
    fn covariance_tracks_cluster_shape() {
        // Stream from a known anisotropic Gaussian; learned covariance
        // must approach it.
        let mut rng = Pcg64::seed(11);
        let cfg = GmmConfig::new(2).with_beta(0.0).with_delta(1.0).without_pruning();
        let mut m = Igmn::new(cfg, &[1.0, 1.0]);
        for _ in 0..5000 {
            let x = rng.normal() * 2.0;
            let y = 0.5 * x + rng.normal() * 0.5;
            m.learn(&[x, y]);
        }
        assert_eq!(m.num_components(), 1);
        let c = m.component_cov(0);
        assert!((c[(0, 0)] - 4.0).abs() < 0.5, "var_x {}", c[(0, 0)]);
        assert!((c[(0, 1)] - 2.0).abs() < 0.4, "cov_xy {}", c[(0, 1)]);
        assert!((c[(1, 1)] - 1.25).abs() < 0.3, "var_y {}", c[(1, 1)]);
    }

    #[test]
    fn engine_matches_serial_bitwise() {
        // Sized so K·D² crosses the engine's parallel-work gate
        // (K ≈ 80, D = 16 → 80·256 ≫ 2¹⁴) and the pool actually runs.
        let d = 16;
        let cfg = GmmConfig::new(d)
            .with_delta(0.05)
            .with_beta(0.2)
            .with_max_components(80)
            .without_pruning();
        let stds = vec![2.0; d];
        let mut serial = Igmn::new(cfg.clone(), &stds);
        let mut pooled = Igmn::new(cfg, &stds).with_engine(EngineConfig::new(3));
        assert_eq!(pooled.engine_threads(), 3);
        let mut rng = Pcg64::seed(12);
        for _ in 0..220 {
            let x: Vec<f64> = (0..d).map(|_| rng.normal() * 6.0).collect();
            assert_eq!(serial.learn(&x), pooled.learn(&x));
        }
        assert_eq!(serial.num_components(), pooled.num_components());
        assert!(serial.num_components() >= 60, "gate never crossed");
        for j in 0..serial.num_components() {
            assert_eq!(serial.component_mean(j), pooled.component_mean(j));
            assert_eq!(serial.store().mat(j), pooled.store().mat(j));
            assert_eq!(serial.component_stats(j), pooled.component_stats(j));
        }
        let probe: Vec<f64> = (0..d).map(|_| rng.normal() * 6.0).collect();
        assert_eq!(serial.log_density(&probe), pooled.log_density(&probe));
        assert_eq!(serial.posteriors(&probe), pooled.posteriors(&probe));
    }

    #[test]
    fn byte_accounting_skips_unused_log_det_lane() {
        let cfg = GmmConfig::new(3).with_beta(0.0).with_delta(1.0).without_pruning();
        let mut m = Igmn::new(cfg, &[1.0, 1.0, 1.0]);
        m.learn(&[0.0, 0.0, 0.0]);
        // D=3: 3 mean + 6 packed + sp floats + u64 age — no log_det,
        // which the covariance baseline never tracks.
        assert_eq!(m.bytes_per_component(), (3 + 6 + 1) * 8 + 8);
        assert_eq!(m.model_bytes(), m.num_components() * m.bytes_per_component());
        // One f64 per component less than the precision path reports.
        let fast = Figmn::new(
            GmmConfig::new(3).with_beta(0.0).with_delta(1.0).without_pruning(),
            &[1.0, 1.0, 1.0],
        );
        assert_eq!(m.bytes_per_component() + 8, fast.bytes_per_component());
    }

    #[test]
    fn mean_converges_to_sample_mean_single_component() {
        // With K=1 the IGMN mean recurrence is exactly the running mean
        // when sp accumulates 1 per point.
        let cfg = GmmConfig::new(1).with_beta(0.0).with_delta(1.0).without_pruning();
        let mut m = Igmn::new(cfg, &[1.0]);
        let xs = [3.0, 5.0, 7.0, 9.0];
        for &x in &xs {
            m.learn(&[x]);
        }
        assert_rel(m.component_mean(0)[0], 6.0, 1e-12);
    }
}
