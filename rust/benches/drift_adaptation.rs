//! Mini-batch learn-pipeline experiment: learn throughput vs block size
//! `b` at fixed K across dimensions, staged blocks vs the online
//! per-point path — the empirical check that freezing a `K×B` distance
//! tile actually amortizes the arena traffic (the online path re-streams
//! every packed precision matrix per *point*; the blocked pass streams
//! them once per *block*). Arms are re-materialized from the *same*
//! arenas, so the comparison measures nothing but the learn mode.
//!
//! Correctness gates ride along (and run even in quick mode):
//!   - `MiniBatch{b: 1}` with decay off bit-identical to `Online`
//!     across 1/2 worker threads,
//!   - `MiniBatch{b: 8}` bit-identical across 1/2/4 worker threads,
//!   - decay + max-age recovers post-shift accuracy on the adversarial
//!     mean-swap `drift_stream` while the non-decayed model does not.
//! The gates are recorded in the JSON `gates` array; the CI bench-diff
//! step fails the job when any gate reports `pass: false`.
//!
//! Acceptance target (full mode): ≥ 2× learn throughput at D ≥ 256
//! with b = 32 vs the online path.
//!
//! Run: `cargo bench --bench drift_adaptation`
//! Quick (CI smoke): `FIGMN_BENCH_QUICK=1 cargo bench --bench drift_adaptation`
//! Writes `BENCH_drift_adaptation.json`.

use figmn::bench_support::{
    quick_mode, rematerialize_learn_mode, synthetic_centers, synthetic_grown_model, time_once,
    write_bench_json, TablePrinter,
};
use figmn::data::synth::{drift_stream, DriftSpec};
use figmn::engine::EngineConfig;
use figmn::gmm::supervised::supervised_figmn;
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture, LearnMode, SearchMode};
use figmn::json::Json;
use figmn::rng::Pcg64;

const SEED: u64 = 42;
const BLOCK_SIZES: [usize; 3] = [1, 8, 32];

/// Update stream: points cycling the model's centers with small noise,
/// so every learn takes the update path in both modes and K stays put.
fn near_center_stream(centers: &[Vec<f64>], n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    (0..n)
        .map(|i| centers[i % centers.len()].iter().map(|&c| c + rng.normal() * 0.5).collect())
        .collect()
}

/// One measured/gated arm: the shared master arenas under `mode`, with
/// an optional worker pool.
fn learn_arm(master: &Figmn, mode: LearnMode, threads: usize) -> Figmn {
    let mut m = rematerialize_learn_mode(master, mode);
    if threads > 1 {
        m.set_engine(Some(EngineConfig::new(threads)));
    }
    m
}

/// Bitwise arena comparison. Non-panicking: gate results must reach
/// the JSON payload (the CI bench-diff step keys off `pass: false`)
/// before `main` aborts, so mismatches print and return `false`.
fn models_identical(a: &Figmn, b: &Figmn, tag: &str) -> bool {
    if a.num_components() != b.num_components() {
        println!("  MISMATCH {tag}: K {} vs {}", a.num_components(), b.num_components());
        return false;
    }
    for j in 0..a.num_components() {
        let same = a.component_mean(j) == b.component_mean(j)
            && a.store().mat(j) == b.store().mat(j)
            && a.component_log_det(j) == b.component_log_det(j)
            && a.component_stats(j) == b.component_stats(j);
        if !same {
            println!("  MISMATCH {tag}: component {j} diverged");
            return false;
        }
    }
    true
}

/// The exactness gates plus the drift-recovery gate. Panicking inside a
/// gate would skip the JSON write, so gates run first and `main`
/// asserts after the payload is on disk.
fn run_gates() -> (Vec<(String, bool)>, f64, f64) {
    let d = 32;
    let k = 32;
    let master = synthetic_grown_model(d, k, SearchMode::Strict, SEED);
    let centers = synthetic_centers(d, k, SEED);
    let stream = near_center_stream(&centers, 200, 9);
    let mut gates = Vec::new();

    // b = 1, decay off ≡ online, bit for bit, serial and pooled.
    {
        let mut online = learn_arm(&master, LearnMode::Online, 1);
        online.learn_batch(&stream);
        let pass = [1usize, 2].iter().all(|&t| {
            let mut staged = learn_arm(&master, LearnMode::MiniBatch { b: 1 }, t);
            staged.learn_batch(&stream);
            models_identical(&online, &staged, &format!("b1 T={t}"))
        });
        gates.push(("minibatch_b1_bitwise".to_string(), pass));
    }

    // Fixed b > 1: every thread count reproduces the serial blocked
    // path bit for bit.
    {
        let mut reference = learn_arm(&master, LearnMode::MiniBatch { b: 8 }, 1);
        reference.learn_batch(&stream);
        let pass = [2usize, 4].iter().all(|&t| {
            let mut pooled = learn_arm(&master, LearnMode::MiniBatch { b: 8 }, t);
            pooled.learn_batch(&stream);
            models_identical(&reference, &pooled, &format!("b8 T={t}"))
        });
        gates.push(("minibatch_thread_determinism".to_string(), pass));
    }

    // Drift recovery: adversarial mean swap — decayed + max-age model
    // recovers post-shift accuracy, the non-decayed one keeps voting
    // its pre-shift mass.
    let (acc_adaptive, acc_stale) = {
        let spec = DriftSpec {
            dim: 5,
            classes: 2,
            instances: 3000,
            shift_at: 1500,
            shift: 0.0,
            swap_classes: true,
            cov_ramp: 1.5,
        };
        let data = drift_stream(&spec, 13);
        let stds = data.feature_stds();
        let train_n = 2700;
        let base = GmmConfig::new(1).with_delta(0.5).with_beta(0.05);
        let mut adaptive = supervised_figmn(
            base.clone().with_decay(0.995).with_max_age(1200),
            &stds,
            spec.classes,
        );
        let mut stale = supervised_figmn(base, &stds, spec.classes);
        adaptive.train_batch(&data.features[..train_n], &data.labels[..train_n]);
        stale.train_batch(&data.features[..train_n], &data.labels[..train_n]);
        let accuracy = |scores: Vec<Vec<f64>>| -> f64 {
            scores
                .iter()
                .zip(&data.labels[train_n..])
                .filter(|(s, &t)| {
                    s.iter()
                        .enumerate()
                        .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                        .unwrap()
                        .0
                        == t
                })
                .count() as f64
                / (data.features.len() - train_n) as f64
        };
        let a = accuracy(adaptive.class_scores_batch(&data.features[train_n..]));
        let s = accuracy(stale.class_scores_batch(&data.features[train_n..]));
        let pass = a >= 0.75 && a >= s + 0.1;
        if !pass {
            println!("  MISMATCH decay_recovery: adaptive {a:.3} vs stale {s:.3}");
        }
        gates.push(("decay_recovery".to_string(), pass));
        (a, s)
    };
    (gates, acc_adaptive, acc_stale)
}

fn main() {
    let quick = quick_mode();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let dims: &[usize] = if quick { &[64] } else { &[64, 256, 1024] };
    // K sized so the per-point distance pass streams more arena bytes
    // than any cache holds at D ≥ 256 — that traffic is what blocking
    // amortizes.
    let k_for = |d: usize| match d {
        64 => 256,
        256 => 96,
        _ => 24,
    };
    let n_for = |d: usize| {
        if quick {
            96
        } else {
            match d {
                64 => 1024,
                256 => 384,
                _ => 96,
            }
        }
    };

    println!(
        "drift_adaptation — learn throughput, online vs staged mini-batch blocks \
         (cores={cores}{})",
        if quick { ", quick mode" } else { "" }
    );

    let (gates, acc_adaptive, acc_stale) = run_gates();
    for (name, pass) in &gates {
        println!("  gate {name}: {}", if *pass { "OK" } else { "FAILED" });
    }
    println!("  drift accuracy: adaptive {acc_adaptive:.3} vs stale {acc_stale:.3}");

    let table =
        TablePrinter::new(&["D", "K", "b", "online/s", "staged/s", "speedup"], &[6, 6, 4, 12, 12, 8]);

    let mut rows: Vec<Json> = Vec::new();
    let mut min_speedup_b32_hi_d = f64::INFINITY;
    for &d in dims {
        let k = k_for(d);
        let n = n_for(d);
        let master = synthetic_grown_model(d, k, SearchMode::Strict, SEED);
        let centers = synthetic_centers(d, k, SEED);
        let updates = near_center_stream(&centers, n, 8);

        // One arm alive at a time (the D=1024 arenas are ~100 MB each).
        let t_online = {
            let mut online = learn_arm(&master, LearnMode::Online, 1);
            time_once(|| online.learn_batch(&updates)).0
        };
        for &b in &BLOCK_SIZES {
            let t_staged = {
                let mut staged = learn_arm(&master, LearnMode::MiniBatch { b }, 1);
                time_once(|| staged.learn_batch(&updates)).0
            };
            let np = n as f64;
            let (online_s, staged_s) = (np / t_online, np / t_staged);
            let speedup = t_online / t_staged;
            if b == 32 && d >= 256 {
                min_speedup_b32_hi_d = min_speedup_b32_hi_d.min(speedup);
            }
            table.row(&[
                d.to_string(),
                k.to_string(),
                b.to_string(),
                format!("{online_s:10.0}"),
                format!("{staged_s:10.0}"),
                format!("{speedup:6.2}×"),
            ]);
            rows.push(Json::obj(vec![
                ("d", d.into()),
                ("k", k.into()),
                ("b", b.into()),
                ("points", n.into()),
                ("online_learn_pts_per_s", online_s.into()),
                ("minibatch_learn_pts_per_s", staged_s.into()),
                ("block_speedup", speedup.into()),
            ]));
        }
    }

    let gate_json: Vec<Json> = gates
        .iter()
        .map(|(name, pass)| {
            Json::obj(vec![("name", name.as_str().into()), ("pass", (*pass).into())])
        })
        .collect();
    let payload = Json::obj(vec![
        ("bench", "drift_adaptation".into()),
        ("quick", quick.into()),
        ("cores", cores.into()),
        (
            "min_speedup_b32_d256_plus",
            if min_speedup_b32_hi_d.is_finite() { min_speedup_b32_hi_d } else { 0.0 }.into(),
        ),
        ("drift_acc_adaptive", acc_adaptive.into()),
        ("drift_acc_stale", acc_stale.into()),
        ("gates", Json::Arr(gate_json)),
        ("rows", Json::Arr(rows)),
    ]);
    match write_bench_json("drift_adaptation", &payload) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }

    // Gates assert *after* the JSON is written so CI sees the failing
    // `gates` entry as well as the panic.
    assert!(gates.iter().all(|(_, p)| *p), "pipeline gate failed (see above)");

    if !quick {
        assert!(
            min_speedup_b32_hi_d >= 2.0,
            "staged b=32 learn speedup at D >= 256 is {min_speedup_b32_hi_d:.2}x (< 2x)"
        );
        println!(
            "drift_adaptation OK — ≥ {min_speedup_b32_hi_d:.2}× staged learn at D ≥ 256, b = 32 \
             (target ≥ 2×)"
        );
    } else {
        println!("drift_adaptation done (quick mode; perf assertion skipped)");
    }
}
