//! Serving concurrency experiment: the event-loop transport under many
//! concurrent connections, with and without read coalescing.
//!
//! Three claims are on trial:
//!
//! 1. **Connection scale** — a fixed driver pool (no thread per
//!    connection) sustains ≥ 4096 open sockets and still serves every
//!    one of them (full mode; poll-based, so the fd table is the only
//!    per-connection cost).
//! 2. **Coalescing throughput** — at high read concurrency the
//!    per-driver coalescers gather single-query `score` requests into
//!    PR 5's 32-query blocked batch jobs, and sustain ≥ 2× the
//!    per-request (`--no-coalesce`) throughput at 256 clients.
//! 3. **Bit-identity** — every byte served over either transport mode
//!    equals the sequential `dispatch()` serialization (the hard
//!    contract; checked here *and* in `tests/serving_transport.rs`).
//!
//! Each sweep point reports throughput plus p50/p95/p99 round-trip
//! latency; the coalesced low-concurrency rows surface the documented
//! size-or-deadline cost (a lone read waits out `max_delay`).
//!
//! Run: `cargo bench --bench serving_concurrency`
//! Quick (CI smoke): `FIGMN_BENCH_QUICK=1 cargo bench --bench serving_concurrency`
//! Writes `BENCH_serving_concurrency.json`.

use figmn::bench_support::{percentile, quick_mode, write_bench_json, TablePrinter};
use figmn::coordinator::poller::raise_nofile;
use figmn::coordinator::protocol::{Request, Response};
use figmn::coordinator::server::dispatch;
use figmn::coordinator::{serve, Metrics, ModelSpec, Registry, Server, ServerConfig};
use figmn::gmm::GmmConfig;
use figmn::json::Json;
use figmn::rng::Pcg64;
use std::io::{BufRead, BufReader, Write as IoWrite};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::Instant;

const N_CLASSES: usize = 2;
const K_TARGET: usize = 32;
const SNAPSHOT_INTERVAL: usize = 16;
const DRIVERS: usize = 2;

fn gmm_config() -> GmmConfig {
    GmmConfig::new(1)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_max_components(K_TARGET)
        .without_pruning()
}

/// Registry with one trained model "serve" (K components, snapshot
/// published over the full warmup) behind a fresh event-loop server.
fn trained_server(d: usize, coalesce: bool) -> (Arc<Registry>, Server) {
    let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
    registry
        .create(
            ModelSpec::new("serve", d, N_CLASSES)
                .with_gmm(gmm_config())
                .with_stds(vec![1.0; d])
                .with_snapshot_interval(SNAPSHOT_INTERVAL),
        )
        .unwrap();
    let router = registry.router("serve").unwrap();
    let mut rng = Pcg64::seed(42);
    let centers: Vec<Vec<f64>> = (0..K_TARGET)
        .map(|_| (0..d).map(|_| rng.normal() * 40.0).collect())
        .collect();
    let warmup = 8 * K_TARGET; // multiple of SNAPSHOT_INTERVAL
    for i in 0..warmup {
        let c = i % K_TARGET;
        let x: Vec<f64> = centers[c].iter().map(|&v| v + rng.normal() * 0.5).collect();
        router.learn(x, c % N_CLASSES).unwrap();
    }
    registry.stats("serve").unwrap();
    let snap = router.shards()[0]
        .wait_snapshot_points(warmup as u64, 5000)
        .expect("snapshot never caught up to the warmup stream");
    assert!(snap.num_components() >= K_TARGET, "stream must grow K = {K_TARGET}");

    let cfg = ServerConfig { drivers: DRIVERS, coalesce, ..ServerConfig::default() };
    let server = serve(registry.clone(), cfg).unwrap();
    (registry, server)
}

fn connect(addr: SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
    let stream = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (reader, stream)
}

fn roundtrip_line(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    line: &str,
) -> String {
    writer.write_all(line.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    let mut buf = String::new();
    reader.read_line(&mut buf).unwrap();
    buf
}

/// Deterministic joint probe (features + one-hot class) for client `c`,
/// request `i`.
fn probe(d: usize, c: usize, i: usize) -> Vec<f64> {
    let mut rng = Pcg64::seed(1000 + (c * 131 + i % 16) as u64);
    let mut x: Vec<f64> = (0..d).map(|_| rng.normal() * 30.0).collect();
    x.extend([1.0, 0.0]);
    x
}

/// The bitwise gate: raw wire bytes ≡ sequential dispatch serialization
/// for a mixed probe set, on whichever server `addr` points at.
fn verify_bit_identity(registry: &Arc<Registry>, addr: SocketAddr, d: usize) {
    let (mut reader, mut writer) = connect(addr);
    for i in 0..12 {
        let req = if i % 3 == 2 {
            let f: Vec<f64> = probe(d, 7, i)[..d].to_vec();
            Request::PredictSnapshot { model: "serve".into(), features: f }
        } else {
            Request::Score { model: "serve".into(), x: probe(d, 7, i) }
        };
        let line = req.to_json().to_string_compact();
        let raw = roundtrip_line(&mut reader, &mut writer, &line);
        let expect = dispatch(req, registry, &None).to_json().to_string_compact();
        assert_eq!(
            raw.trim_end_matches('\n'),
            expect,
            "wire response diverged from sequential dispatch"
        );
    }
    println!("  bit-identity OK (wire bytes ≡ sequential dispatch)");
}

/// One sweep point: `clients` threads, each with its own connection,
/// issuing `per_client` sequential score round-trips. Returns
/// (reqs/sec, per-request latency samples in seconds).
fn sweep_point(addr: SocketAddr, d: usize, clients: usize, per_client: usize) -> (f64, Vec<f64>) {
    let barrier = Arc::new(Barrier::new(clients + 1));
    let mut handles = Vec::new();
    for c in 0..clients {
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let (mut reader, mut writer) = connect(addr);
            // Pre-serialize outside the timed region.
            let lines: Vec<String> = (0..16)
                .map(|i| {
                    Request::Score { model: "serve".into(), x: probe(d, c, i) }
                        .to_json()
                        .to_string_compact()
                })
                .collect();
            barrier.wait();
            let mut lat = Vec::with_capacity(per_client);
            for i in 0..per_client {
                let t0 = Instant::now();
                let resp = roundtrip_line(&mut reader, &mut writer, &lines[i % lines.len()]);
                lat.push(t0.elapsed().as_secs_f64());
                assert!(resp.contains("density"), "unexpected response: {resp}");
            }
            lat
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    let mut latencies = Vec::with_capacity(clients * per_client);
    for h in handles {
        latencies.extend(h.join().unwrap());
    }
    let secs = t0.elapsed().as_secs_f64();
    ((clients * per_client) as f64 / secs, latencies)
}

/// Open `n` idle connections and prove each is live with one ping.
fn open_idle_flock(addr: SocketAddr, n: usize) -> Vec<(BufReader<TcpStream>, TcpStream)> {
    let ping = Request::Ping.to_json().to_string_compact();
    let mut flock = Vec::with_capacity(n);
    for _ in 0..n {
        let (mut reader, mut writer) = connect(addr);
        let resp = roundtrip_line(&mut reader, &mut writer, &ping);
        assert!(resp.contains("pong"), "idle connection not served: {resp}");
        flock.push((reader, writer));
    }
    flock
}

fn main() {
    let quick = quick_mode();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let d = if quick { 62 } else { 254 }; // joint = d + N_CLASSES
    let client_counts: &[usize] = if quick { &[4, 16] } else { &[16, 64, 256] };
    let per_client = if quick { 50 } else { 200 };
    let idle_target = if quick { 256 } else { 4096 };

    // Both ends of every socket live in this process: ~2 fds per idle
    // connection plus the sweep clients and headroom.
    let want_fds = (2 * idle_target + 2048) as u64;
    let fd_limit = raise_nofile(want_fds);
    let idle_n = if fd_limit >= want_fds {
        idle_target
    } else {
        let capped = ((fd_limit.saturating_sub(1024)) / 2) as usize;
        eprintln!(
            "note: RLIMIT_NOFILE={fd_limit} caps the idle flock at {capped} \
             (wanted {idle_target})"
        );
        capped.min(idle_target)
    };

    println!(
        "serving_concurrency — event-loop transport, {DRIVERS} drivers \
         (D={d}+{N_CLASSES}, K={K_TARGET}, idle={idle_n}, cores={cores}{})",
        if quick { ", quick mode" } else { "" }
    );

    let table = TablePrinter::new(
        &["mode", "clients", "reqs/s", "p50 ms", "p95 ms", "p99 ms"],
        &[12, 8, 11, 9, 9, 9],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut gates: Vec<Json> = Vec::new();
    let mut rate_at_max: [f64; 2] = [0.0, 0.0]; // [coalesced, per_request]

    for (mode_i, &coalesce) in [true, false].iter().enumerate() {
        let mode = if coalesce { "coalesced" } else { "per_request" };
        let (registry, server) = trained_server(d, coalesce);
        let addr = server.local_addr;

        println!("{mode}:");
        verify_bit_identity(&registry, addr, d);

        // The connection-scale leg rides the coalesced server only —
        // the transport is identical in both modes.
        let flock = if coalesce { open_idle_flock(addr, idle_n) } else { Vec::new() };

        for &clients in client_counts {
            let (rate, mut lat) = sweep_point(addr, d, clients, per_client);
            if clients == *client_counts.last().unwrap() {
                rate_at_max[mode_i] = rate;
            }
            let p50 = percentile(&mut lat, 50.0);
            let p95 = percentile(&mut lat, 95.0);
            let p99 = percentile(&mut lat, 99.0);
            table.row(&[
                mode.to_string(),
                clients.to_string(),
                format!("{rate:10.0}"),
                format!("{:8.3}", p50 * 1e3),
                format!("{:8.3}", p95 * 1e3),
                format!("{:8.3}", p99 * 1e3),
            ]);
            rows.push(Json::obj(vec![
                ("mode", mode.into()),
                ("clients", clients.into()),
                ("d", Json::from(d)),
                ("k", Json::from(K_TARGET)),
                ("reqs_per_s", rate.into()),
                ("p50_s", p50.into()),
                ("p95_s", p95.into()),
                ("p99_s", p99.into()),
            ]));
        }

        if coalesce {
            // Liveness after the sweep: a sample of the idle flock must
            // still answer (slow sockets cannot have been starved out).
            let ping = Request::Ping.to_json().to_string_compact();
            let step = (flock.len() / 64).max(1);
            let mut checked = 0usize;
            let mut flock = flock;
            for (reader, writer) in flock.iter_mut().step_by(step) {
                let resp = roundtrip_line(reader, writer, &ping);
                assert!(resp.contains("pong"), "idle connection starved: {resp}");
                checked += 1;
            }
            println!(
                "  idle flock OK — {} connections held, {checked} re-pinged after sweep",
                flock.len()
            );
            // Judged against what the fd limit let us attempt: a capped
            // rlimit is environmental, not a transport failure.
            let sustained = flock.len() >= idle_n;
            gates.push(Json::obj(vec![
                ("name", format!("sustains_{idle_target}_connections").into()),
                ("pass", sustained.into()),
                ("held", Json::from(flock.len())),
                ("attempted", Json::from(idle_n)),
                ("target", Json::from(idle_target)),
            ]));
            if !quick && fd_limit >= want_fds {
                assert!(sustained, "idle flock fell short: {} < {idle_target}", flock.len());
            }
            let m = registry.metrics().snapshot();
            assert!(m.coalesced_batches > 0, "coalesced mode never batched");
            println!(
                "  coalescing: {} reads in {} batches (mean {:.1}/batch)",
                m.coalesced_reads,
                m.coalesced_batches,
                m.coalesced_reads as f64 / m.coalesced_batches as f64
            );
        }
        server.shutdown();
    }

    gates.push(Json::obj(vec![
        ("name", "bitwise_wire_vs_sequential_dispatch".into()),
        ("pass", true.into()), // asserted above, both modes
    ]));
    let max_clients = *client_counts.last().unwrap();
    let speedup = rate_at_max[0] / rate_at_max[1];
    if !quick {
        // Quick mode tops out at 16 clients, where the size-or-deadline
        // tradeoff legitimately favors per-request — the 2× claim (and
        // its gate) only applies at high concurrency.
        gates.push(Json::obj(vec![
            ("name", "coalesced_2x_at_max_clients".into()),
            ("pass", (speedup >= 2.0).into()),
            ("clients", max_clients.into()),
            ("speedup", speedup.into()),
        ]));
    }

    let payload = Json::obj(vec![
        ("bench", "serving_concurrency".into()),
        ("quick", quick.into()),
        ("cores", cores.into()),
        ("d", Json::from(d)),
        ("k", Json::from(K_TARGET)),
        ("drivers", Json::from(DRIVERS)),
        ("idle_connections", Json::from(idle_n)),
        ("rows", Json::Arr(rows)),
        ("gates", Json::Arr(gates)),
    ]);
    match write_bench_json("serving_concurrency", &payload) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }

    if !quick {
        assert!(
            speedup >= 2.0,
            "coalesced throughput is {speedup:.2}× (< 2×) per-request at \
             {max_clients} clients, D={d}, K={K_TARGET}"
        );
        println!(
            "serving_concurrency OK — {speedup:.2}× coalesced vs per-request \
             at {max_clients} clients"
        );
    } else {
        println!(
            "serving_concurrency done (quick mode; coalesced/per-request \
             ratio {speedup:.2}× at {max_clients} clients — gate not enforced)"
        );
    }
}
