//! K-scaling experiment for the candidate-index search: learn + score
//! throughput vs K (components) at fixed D, strict full-K sweeps vs
//! `SearchMode::TopC` — the empirical check that the index actually
//! breaks the O(K·D²)-per-point wall (per-point cost `O(C·D²)` plus a
//! cheap candidate lookup). Arms are re-materialized from the *same*
//! arenas, so the comparison measures nothing but the search mode.
//!
//! A `topc_minibatch` series rides along: TopC learn throughput,
//! per-point (`Online`) vs the masked union-row blocked pass
//! (`MiniBatch{b}`), on a bursty stream (blocks share candidate rows,
//! the regime the union pass optimizes). Same candidate arithmetic,
//! bit-identical results — the win is streaming each union row's
//! packed arena data once per block.
//!
//! Correctness gates ride along (and run even in quick mode):
//!   - strict results bit-identical across 1/2/4 worker threads,
//!   - TopC results bit-identical across 1/2/4 worker threads,
//!   - TopC with c ≥ K bit-identical to the strict full sweep
//!     (create + update decisions, arenas, and scores),
//!   - TopC scores within 1e-9 of strict on near-center probes,
//!   - TopC×MiniBatch (b ∈ {1, 32}, threads {1, 4}) bit-identical to
//!     the TopC per-point path on the bursty stream,
//!   - a create-only churn stream completes with **zero** full index
//!     rebuilds (every create appends incrementally).
//! The gates are recorded in the JSON `gates` array; the CI bench-diff
//! step fails the job when any gate reports `pass: false`.
//!
//! Acceptance targets (full mode): ≥ 3× combined learn+score
//! throughput at K = 4096, D = 64 with TopC(C = 64) vs the strict
//! full-K sweep, and ≥ 2× blocked-vs-per-point TopC learn throughput
//! at K = 4096, C = 64, b = 32.
//!
//! Run: `cargo bench --bench scaling_k`
//! Quick (CI smoke): `FIGMN_BENCH_QUICK=1 cargo bench --bench scaling_k`
//! Writes `BENCH_scaling_k.json`.

use figmn::bench_support::{
    quick_mode, rematerialize, rematerialize_learn_mode, synthetic_centers,
    synthetic_grown_model, time_once, write_bench_json, TablePrinter,
};
use figmn::engine::EngineConfig;
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture, LearnMode, SearchMode};
use figmn::json::Json;
use figmn::rng::Pcg64;

const DIM: usize = 64;
const TOP_C: usize = 64;
const SEED: u64 = 42;

/// Points cycling the model's centers with small noise — each lands in
/// χ² range of exactly one component, so learns take the update path
/// in both modes and scores have one dominant term.
fn near_center_stream(centers: &[Vec<f64>], n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    (0..n)
        .map(|i| centers[i % centers.len()].iter().map(|&c| c + rng.normal() * 0.5).collect())
        .collect()
}

/// Bursty variant: `burst` consecutive points share one center before
/// the stream moves to the next — the temporal locality the masked
/// TopC block pass exploits (a block's per-point candidate sets
/// overlap, so the union has ~C rows masked by ~`burst` points each
/// instead of `burst`·C rows masked once).
fn bursty_stream(centers: &[Vec<f64>], n: usize, burst: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    (0..n)
        .map(|i| {
            let c = &centers[(i / burst) % centers.len()];
            c.iter().map(|&v| v + rng.normal() * 0.5).collect()
        })
        .collect()
}

/// A TopC arm staged through the mini-batch pipeline (same arenas,
/// same candidate arithmetic — only the write-path blocking differs).
fn minibatch_arm(master: &Figmn, c: usize, b: usize, threads: usize) -> Figmn {
    let mut m = rematerialize_learn_mode(
        &rematerialize(master, SearchMode::TopC { c }),
        LearnMode::MiniBatch { b },
    );
    if threads > 1 {
        m.set_engine(Some(EngineConfig::new(threads)));
    }
    m
}

/// One measured/gated arm: the shared master arenas under `mode`, with
/// an optional worker pool.
fn arm(master: &Figmn, mode: SearchMode, threads: usize) -> Figmn {
    let mut m = rematerialize(master, mode);
    if threads > 1 {
        m.set_engine(Some(EngineConfig::new(threads)));
    }
    m
}

/// Bitwise arena comparison. Non-panicking: gate results must reach
/// the JSON payload (the CI bench-diff step keys off `pass: false`)
/// before `main` aborts, so mismatches print and return `false`.
fn models_identical(a: &Figmn, b: &Figmn, tag: &str) -> bool {
    if a.num_components() != b.num_components() {
        println!("  MISMATCH {tag}: K {} vs {}", a.num_components(), b.num_components());
        return false;
    }
    for j in 0..a.num_components() {
        let same = a.component_mean(j) == b.component_mean(j)
            && a.component_lambda(j).as_slice() == b.component_lambda(j).as_slice()
            && a.component_log_det(j) == b.component_log_det(j)
            && a.component_stats(j) == b.component_stats(j);
        if !same {
            println!("  MISMATCH {tag}: component {j} diverged");
            return false;
        }
    }
    true
}

/// Strict vs TopC thread determinism + the c ≥ K bitwise-identity gate,
/// on a small fixed K so the gates stay cheap enough for CI quick mode.
/// Panicking inside a gate would skip the JSON write, so gates run
/// first and `main` asserts after the payload is on disk.
fn run_gates(k_gate: usize) -> Vec<(String, bool)> {
    let master = synthetic_grown_model(DIM, k_gate, SearchMode::Strict, SEED);
    let centers = synthetic_centers(DIM, k_gate, SEED);
    let stream = near_center_stream(&centers, 200, 9);

    let mut gates = Vec::new();

    // Thread determinism, both modes: same stream through 1/2/4-thread
    // arms must leave bit-identical arenas.
    for (name, mode) in [
        ("strict_thread_determinism", SearchMode::Strict),
        ("topc_thread_determinism", SearchMode::TopC { c: (k_gate / 2).clamp(1, TOP_C) }),
    ] {
        let mut reference = arm(&master, mode, 1);
        reference.learn_batch(&stream);
        let pass = [2usize, 4].iter().all(|&t| {
            let mut pooled = arm(&master, mode, t);
            pooled.learn_batch(&stream);
            models_identical(&reference, &pooled, &format!("{name} T={t}"))
        });
        gates.push((name.to_string(), pass));
    }

    // c ≥ K: the candidate set is all of 0..K ascending — the same
    // arithmetic in the same order as the strict sweep, so arenas and
    // scores must match bit for bit, through both the at-cap update
    // path and a from-scratch create/update mix.
    {
        let mut strict = arm(&master, SearchMode::Strict, 1);
        let mut full_c = arm(&master, SearchMode::TopC { c: k_gate }, 1);
        strict.learn_batch(&stream);
        full_c.learn_batch(&stream);
        let mut pass = models_identical(&strict, &full_c, "full-c at cap");
        let probes = near_center_stream(&centers, 64, 10);
        pass &= strict.score_batch(&probes) == full_c.score_batch(&probes);

        // From scratch: the first k_gate points create (novelty), the
        // rest update at cap — both learn outcomes and final arenas
        // must track the strict model exactly.
        let base = GmmConfig::new(DIM)
            .with_delta(0.5)
            .with_beta(0.05)
            .with_max_components(k_gate)
            .without_pruning();
        let mut s2 = Figmn::new(base.clone(), &vec![1.0; DIM]);
        let mut t2 = Figmn::new(
            base.with_search_mode(SearchMode::TopC { c: k_gate }),
            &vec![1.0; DIM],
        );
        for x in centers.iter().chain(stream.iter()) {
            let (a, b) = (s2.learn(x), t2.learn(x));
            pass &= a == b;
        }
        pass &= models_identical(&s2, &t2, "full-c from scratch");
        gates.push(("topc_full_c_bitwise".to_string(), pass));
    }

    // TopC×MiniBatch: the masked union-row blocked pass must be
    // bit-identical to the TopC per-point path — on the bursty stream
    // it optimizes, at b ∈ {1, 32}, serial and pooled.
    {
        let c = (k_gate / 2).clamp(1, TOP_C);
        let bursty = bursty_stream(&centers, 192, 32, 11);
        let mut per_point = arm(&master, SearchMode::TopC { c }, 1);
        per_point.learn_batch(&bursty);
        let mut pass = true;
        for b in [1usize, 32] {
            for t in [1usize, 4] {
                let mut blocked = minibatch_arm(&master, c, b, t);
                blocked.learn_batch(&bursty);
                pass &= models_identical(
                    &per_point,
                    &blocked,
                    &format!("topc_minibatch b={b} T={t}"),
                );
            }
        }
        gates.push(("topc_minibatch_bitwise".to_string(), pass));
    }

    // Incremental index maintenance: a create-only churn stream (every
    // point novel) must complete with zero full rebuilds — creates
    // append into the index instead of invalidating it.
    {
        let d = DIM;
        let n = 64usize;
        let cfg = GmmConfig::new(d)
            .with_delta(0.5)
            .with_beta(0.05)
            .with_search_mode(SearchMode::TopC { c: 8 })
            .with_learn_mode(LearnMode::MiniBatch { b: 8 })
            .without_pruning();
        let mut churn = Figmn::new(cfg, &vec![1.0; d]);
        let mut rng = Pcg64::seed(13);
        // 1e3-scale means at σ = 0.5: every draw is novel.
        let stream: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.normal() * 1e3).collect()).collect();
        churn.learn_batch(&stream);
        let counters = churn.index_counters();
        let pass = churn.num_components() == n
            && counters.rebuilds == 0
            && counters.incremental_updates == (n - 1) as u64;
        if !pass {
            println!(
                "  MISMATCH churn: K={} rebuilds={} incremental={}",
                churn.num_components(),
                counters.rebuilds,
                counters.incremental_updates
            );
        }
        gates.push(("topc_churn_zero_rebuilds".to_string(), pass));
    }
    gates
}

fn main() {
    let quick = quick_mode();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let ks: &[usize] = if quick { &[64, 256] } else { &[64, 256, 1024, 4096, 16384] };
    let n_for = |k: usize| if quick { 120 } else { (400_000 / k).clamp(100, 2000) };
    let k_gate = if quick { 64 } else { 512 };

    println!(
        "scaling_k — learn+score throughput, strict vs TopC(C={TOP_C}) \
         (D={DIM}, cores={cores}{})",
        if quick { ", quick mode" } else { "" }
    );

    let gates = run_gates(k_gate);
    for (name, pass) in &gates {
        println!("  gate {name}: {}", if *pass { "OK" } else { "FAILED" });
    }

    let table = TablePrinter::new(
        &["K", "learn/s", "topc", "score/s", "topc", "speedup"],
        &[6, 12, 12, 12, 12, 8],
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut speedup_at_4096: f64 = 0.0;
    let mut max_score_diff: f64 = 0.0;
    for &k in ks {
        let n = n_for(k);
        let master = synthetic_grown_model(DIM, k, SearchMode::Strict, SEED);
        let centers = synthetic_centers(DIM, k, SEED);
        let probes = near_center_stream(&centers, n, 7);
        let updates = near_center_stream(&centers, n, 8);

        // One arm alive at a time (the K=16384 arenas are ~300 MB
        // each): score first (immutable), then learn on the same arm.
        let (t_score_s, t_learn_s, scores_s) = {
            let mut strict = arm(&master, SearchMode::Strict, 1);
            let (ts, scores) = time_once(|| strict.score_batch(&probes));
            let (tl, _) = time_once(|| strict.learn_batch(&updates));
            (ts, tl, scores)
        };
        let (t_score_c, t_learn_c, scores_c) = {
            let mut topc = arm(&master, SearchMode::TopC { c: TOP_C }, 1);
            let (ts, scores) = time_once(|| topc.score_batch(&probes));
            let (tl, _) = time_once(|| topc.learn_batch(&updates));
            (ts, tl, scores)
        };

        let diff = scores_s
            .iter()
            .zip(scores_c.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0_f64, f64::max);
        max_score_diff = max_score_diff.max(diff);

        let np = n as f64;
        let (learn_s, learn_c) = (np / t_learn_s, np / t_learn_c);
        let (score_s, score_c) = (np / t_score_s, np / t_score_c);
        let combined = (t_learn_s + t_score_s) / (t_learn_c + t_score_c);
        if k == 4096 {
            speedup_at_4096 = combined;
        }
        table.row(&[
            k.to_string(),
            format!("{learn_s:10.0}"),
            format!("{learn_c:10.0}"),
            format!("{score_s:10.0}"),
            format!("{score_c:10.0}"),
            format!("{combined:6.2}×"),
        ]);
        rows.push(Json::obj(vec![
            ("d", DIM.into()),
            ("k", k.into()),
            ("c", TOP_C.into()),
            ("points", n.into()),
            ("strict_learn_pts_per_s", learn_s.into()),
            ("topc_learn_pts_per_s", learn_c.into()),
            ("strict_score_pts_per_s", score_s.into()),
            ("topc_score_pts_per_s", score_c.into()),
            ("combined_speedup", combined.into()),
            ("max_abs_score_diff", diff.into()),
        ]));
    }

    // --- topc_minibatch series: per-point vs masked blocked learn ---
    // Bursty streams (32-point bursts) so blocks have the candidate
    // overlap the union pass is built for; per-point and blocked arms
    // consume the *same* stream, so the ratio isolates the write-path
    // blocking. b = 1 routes through the per-point body (speedup ~1,
    // the exactness anchor); b = 32 is the masked blocked pass.
    println!("\ntopc_minibatch — TopC(C={TOP_C}) learn, per-point vs blocked (bursty stream)");
    let mb_table = TablePrinter::new(
        &["K", "b", "perpoint/s", "blocked/s", "speedup"],
        &[6, 4, 12, 12, 8],
    );
    let mut mb_rows: Vec<Json> = Vec::new();
    let mut mb_speedup_at_4096: f64 = 0.0;
    for &k in ks.iter().filter(|&&k| quick || k >= 256) {
        let n = n_for(k);
        let master = synthetic_grown_model(DIM, k, SearchMode::Strict, SEED);
        let centers = synthetic_centers(DIM, k, SEED);
        let bursty = bursty_stream(&centers, n, 32, 8);

        let t_per_point = {
            let mut per_point = arm(&master, SearchMode::TopC { c: TOP_C }, 1);
            time_once(|| per_point.learn_batch(&bursty)).0
        };
        for b in [1usize, 32] {
            let t_blocked = {
                let mut blocked = minibatch_arm(&master, TOP_C, b, 1);
                time_once(|| blocked.learn_batch(&bursty)).0
            };
            let np = n as f64;
            let speedup = t_per_point / t_blocked;
            if k == 4096 && b == 32 {
                mb_speedup_at_4096 = speedup;
            }
            mb_table.row(&[
                k.to_string(),
                b.to_string(),
                format!("{:10.0}", np / t_per_point),
                format!("{:10.0}", np / t_blocked),
                format!("{speedup:6.2}×"),
            ]);
            mb_rows.push(Json::obj(vec![
                ("d", DIM.into()),
                ("k", k.into()),
                ("c", TOP_C.into()),
                ("b", b.into()),
                ("points", n.into()),
                ("perpoint_learn_pts_per_s", (np / t_per_point).into()),
                ("blocked_learn_pts_per_s", (np / t_blocked).into()),
                ("learn_speedup", speedup.into()),
            ]));
        }
    }

    let score_tol_pass = max_score_diff < 1e-9;
    let mut gate_json: Vec<Json> = gates
        .iter()
        .map(|(name, pass)| {
            Json::obj(vec![("name", name.as_str().into()), ("pass", (*pass).into())])
        })
        .collect();
    gate_json.push(Json::obj(vec![
        ("name", "topc_score_tolerance".into()),
        ("pass", score_tol_pass.into()),
    ]));

    let payload = Json::obj(vec![
        ("bench", "scaling_k".into()),
        ("dim", DIM.into()),
        ("top_c", TOP_C.into()),
        ("quick", quick.into()),
        ("cores", cores.into()),
        ("speedup_d64_k4096", speedup_at_4096.into()),
        ("minibatch_learn_speedup_k4096_b32", mb_speedup_at_4096.into()),
        ("max_abs_score_diff", max_score_diff.into()),
        ("gates", Json::Arr(gate_json)),
        ("rows", Json::Arr(rows)),
        ("topc_minibatch", Json::Arr(mb_rows)),
    ]);
    match write_bench_json("scaling_k", &payload) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }

    // Gates assert *after* the JSON is written so CI sees the failing
    // `gates` entry as well as the panic.
    assert!(gates.iter().all(|(_, p)| *p), "bitwise gate failed (see above)");
    assert!(
        score_tol_pass,
        "TopC scores drifted {max_score_diff:.3e} from strict (tolerance 1e-9)"
    );

    if !quick {
        assert!(
            speedup_at_4096 >= 3.0,
            "TopC(C={TOP_C}) combined learn+score speedup at D={DIM}, K=4096 \
             is {speedup_at_4096:.2}× (< 3×)"
        );
        assert!(
            mb_speedup_at_4096 >= 2.0,
            "masked blocked TopC learn at D={DIM}, K=4096, C={TOP_C}, b=32 \
             is {mb_speedup_at_4096:.2}× per-point (< 2×)"
        );
        println!(
            "scaling_k OK — {speedup_at_4096:.2}× combined at K=4096 (target ≥ 3×), \
             {mb_speedup_at_4096:.2}× blocked TopC learn (target ≥ 2×)"
        );
    } else {
        println!("scaling_k done (quick mode; perf assertion skipped)");
    }
}
