//! Component-scaling experiment for the sharded engine: learn
//! throughput vs K (components) × worker threads at fixed D, exercising
//! the batch API end to end. This is the empirical check for the
//! engine's reason to exist — per-point work is `O(KD²)` and
//! embarrassingly parallel in K — plus a bitwise determinism check
//! (thread count must never change results).
//!
//! Acceptance target (full mode, ≥ 4 cores): ≥ 2× learn throughput at
//! D = 64, K ≥ 32 with 4 worker threads vs. the single-thread path.
//!
//! Run: `cargo bench --bench scaling_components`
//! Quick (CI smoke): `FIGMN_BENCH_QUICK=1 cargo bench --bench scaling_components`
//! Writes `BENCH_scaling_components.json`.

use figmn::bench_support::{quick_mode, write_bench_json, TablePrinter};
use figmn::engine::EngineConfig;
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture};
use figmn::json::Json;
use figmn::rng::Pcg64;
use std::time::Instant;

const DIM: usize = 64;

/// K well-separated seed points (one component each) plus an update
/// stream cycling the centers — K stays pinned at `k` via the cap.
fn build_stream(d: usize, k: usize, n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let mut rng = Pcg64::seed(seed);
    let centers: Vec<Vec<f64>> =
        (0..k).map(|_| (0..d).map(|_| rng.normal() * 40.0).collect()).collect();
    let updates: Vec<Vec<f64>> = (0..n)
        .map(|i| centers[i % k].iter().map(|&c| c + rng.normal() * 0.5).collect())
        .collect();
    (centers, updates)
}

fn fresh_model(d: usize, k: usize, threads: usize, seeds: &[Vec<f64>]) -> Figmn {
    let cfg = GmmConfig::new(d)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_max_components(k)
        .without_pruning();
    let stds = vec![1.0; d];
    let mut m = Figmn::new(cfg, &stds);
    if threads > 1 {
        m.set_engine(Some(EngineConfig::new(threads)));
    }
    for s in seeds {
        m.learn(s);
    }
    assert_eq!(m.num_components(), k, "seeding must create exactly K components");
    m
}

fn learn_throughput(m: &mut Figmn, updates: &[Vec<f64>]) -> f64 {
    let t = Instant::now();
    m.learn_batch(updates);
    updates.len() as f64 / t.elapsed().as_secs_f64()
}

fn assert_models_identical(a: &Figmn, b: &Figmn, tag: &str) {
    assert_eq!(a.num_components(), b.num_components(), "{tag}: K diverged");
    for j in 0..a.num_components() {
        assert_eq!(a.component_mean(j), b.component_mean(j), "{tag}: mean[{j}]");
        assert_eq!(
            a.component_lambda(j).as_slice(),
            b.component_lambda(j).as_slice(),
            "{tag}: lambda[{j}]"
        );
        assert!(
            a.component_log_det(j) == b.component_log_det(j),
            "{tag}: log_det[{j}]"
        );
        assert_eq!(a.component_stats(j), b.component_stats(j), "{tag}: sp/v[{j}]");
    }
}

fn main() {
    let quick = quick_mode();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let ks: &[usize] = if quick { &[32] } else { &[8, 32, 128] };
    let threads: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4] };
    let n_for = |k: usize| if quick { 300 } else { (200_000 / k).clamp(500, 6000) };

    println!(
        "scaling_components — learn throughput vs K × threads (D={DIM}, cores={cores}{})",
        if quick { ", quick mode" } else { "" }
    );
    let table = TablePrinter::new(&["K", "threads", "pts/s", "speedup"], &[6, 8, 12, 10]);

    let mut rows: Vec<Json> = Vec::new();
    let mut best_speedup_k32_t4: f64 = 0.0;
    for &k in ks {
        let n = n_for(k);
        let (seeds, updates) = build_stream(DIM, k, n, 42);
        let mut serial_rate = 0.0;
        for &t in threads {
            let mut model = fresh_model(DIM, k, t, &seeds);
            let rate = learn_throughput(&mut model, &updates);
            if t == 1 {
                serial_rate = rate;
            }
            let speedup = rate / serial_rate;
            if t == 4 && k >= 32 {
                best_speedup_k32_t4 = best_speedup_k32_t4.max(speedup);
            }
            table.row(&[
                k.to_string(),
                t.to_string(),
                format!("{rate:10.0}"),
                format!("{speedup:7.2}×"),
            ]);
            rows.push(Json::obj(vec![
                ("d", DIM.into()),
                ("k", k.into()),
                ("threads", t.into()),
                ("points", n.into()),
                ("pts_per_sec", rate.into()),
                ("speedup_vs_serial", speedup.into()),
            ]));
        }

        // Determinism: the same (shortened) stream through serial, 2- and
        // 4-thread engines must yield bit-identical models.
        let short = &updates[..updates.len().min(200)];
        let mut reference = fresh_model(DIM, k, 1, &seeds);
        reference.learn_batch(short);
        for t in [2usize, 4] {
            let mut pooled = fresh_model(DIM, k, t, &seeds);
            pooled.learn_batch(short);
            assert_models_identical(&reference, &pooled, &format!("K={k} T={t}"));
        }
        println!("  determinism OK at K={k} (threads 1/2/4 bit-identical)");
    }

    let payload = Json::obj(vec![
        ("bench", "scaling_components".into()),
        ("dim", DIM.into()),
        ("quick", quick.into()),
        ("cores", cores.into()),
        ("speedup_d64_k32plus_t4", best_speedup_k32_t4.into()),
        ("rows", Json::Arr(rows)),
    ]);
    match write_bench_json("scaling_components", &payload) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }

    if !quick && cores >= 4 {
        assert!(
            best_speedup_k32_t4 >= 2.0,
            "4-thread learn speedup at D=64, K≥32 is {best_speedup_k32_t4:.2}× (< 2×)"
        );
        println!(
            "scaling_components OK — {best_speedup_k32_t4:.2}× with 4 threads at D=64, K≥32"
        );
    } else {
        println!(
            "scaling_components done (speedup {best_speedup_k32_t4:.2}×; \
             assertion skipped: quick={quick}, cores={cores})"
        );
    }
}
