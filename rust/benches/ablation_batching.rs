//! Coordinator ablation (DESIGN.md row S2): micro-batching policy —
//! batch size × flush deadline vs serving throughput and tail latency,
//! measured through the real worker/router stack with concurrent
//! clients.
//!
//! Run: `cargo bench --bench ablation_batching`

use figmn::bench_support::{percentile, TablePrinter};
use figmn::coordinator::batcher::BatcherConfig;
use figmn::coordinator::metrics::Metrics;
use figmn::coordinator::worker::{Worker, WorkerConfig};
use figmn::gmm::GmmConfig;
use figmn::rng::Pcg64;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let clients = 4usize;
    let requests_per_client = 500usize;

    println!(
        "S2 — batching ablation ({clients} concurrent clients × {requests_per_client} predicts)"
    );
    let t = TablePrinter::new(
        &["max_batch", "max_delay", "throughput", "p50 lat", "p99 lat", "mean batch"],
        &[10, 10, 14, 10, 10, 10],
    );

    for (max_batch, delay_us) in
        [(1usize, 0u64), (8, 200), (8, 2000), (32, 200), (32, 2000), (128, 2000)]
    {
        let metrics = Arc::new(Metrics::new());
        let gmm = GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning();
        let mut wc = WorkerConfig::new(2, 3, gmm, vec![3.0, 3.0]);
        wc.batcher = BatcherConfig {
            max_batch,
            max_delay: Duration::from_micros(delay_us),
        };
        let worker = Worker::spawn(wc, metrics.clone());

        // Warm the model.
        let mut rng = Pcg64::seed(1);
        let centers = [[0.0, 0.0], [7.0, 7.0], [0.0, 7.0]];
        for i in 0..300 {
            let c = i % 3;
            worker
                .handle
                .learn(
                    vec![centers[c][0] + rng.normal() * 0.7, centers[c][1] + rng.normal() * 0.7],
                    c,
                )
                .unwrap();
        }
        let _ = worker.handle.stats(); // barrier: all learns applied

        // Concurrent predict load.
        let started = Instant::now();
        let mut joins = Vec::new();
        for t_id in 0..clients {
            let h = worker.handle.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Pcg64::seed(100 + t_id as u64);
                let mut lats = Vec::with_capacity(requests_per_client);
                for _ in 0..requests_per_client {
                    let x = vec![rng.uniform_in(-1.0, 8.0), rng.uniform_in(-1.0, 8.0)];
                    let t0 = Instant::now();
                    let scores = h.predict(x).unwrap();
                    lats.push(t0.elapsed().as_secs_f64());
                    assert_eq!(scores.len(), 3);
                }
                lats
            }));
        }
        let mut lats: Vec<f64> = joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
        let wall = started.elapsed().as_secs_f64();
        let total = clients * requests_per_client;
        let snap = metrics.snapshot();
        t.row(&[
            max_batch.to_string(),
            format!("{delay_us}µs"),
            format!("{:9.0}/s", total as f64 / wall),
            format!("{:7.0}µs", percentile(&mut lats, 50.0) * 1e6),
            format!("{:7.0}µs", percentile(&mut lats, 99.0) * 1e6),
            format!("{:7.2}", snap.mean_batch),
        ]);
        worker.join();
    }
    println!(
        "\n(closed-loop clients: each blocks on its reply, so in-flight ≤ #clients and the \
         deadline is pure added latency when per-item cost is tiny — batching pays only for \
         expensive items (high-D XLA scoring) or open-loop traffic; see EXPERIMENTS.md §S2)"
    );
}
