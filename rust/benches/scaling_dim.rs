//! Complexity-scaling experiment (DESIGN.md row S1): per-point training
//! cost vs dimensionality for both variants, with fitted power-law
//! exponents — the direct empirical check of the paper's O(NKD³) →
//! O(NKD²) claim (its central contribution).
//!
//! Run: `cargo bench --bench scaling_dim`
//! Quick (CI smoke): `FIGMN_BENCH_QUICK=1 cargo bench --bench scaling_dim`
//! Writes `BENCH_scaling_dim.json`.

use figmn::bench_support::{fit_power_law, quick_mode, write_bench_json, TablePrinter};
use figmn::gmm::{ComponentStore, Figmn, GmmConfig, Igmn, IncrementalMixture};
use figmn::json::Json;
use figmn::rng::Pcg64;
use std::time::Instant;

/// Returns `(seconds per point, arena bytes per component)` — the
/// second term makes the packed layout's ~2× memory saving visible in
/// `BENCH_scaling_dim.json` alongside the speed numbers.
fn per_point_seconds(dim: usize, n: usize, fast: bool, seed: u64) -> (f64, usize) {
    let cfg = GmmConfig::new(dim).with_delta(1.0).with_beta(0.0).without_pruning();
    let stds = vec![1.0; dim];
    let mut rng = Pcg64::seed(seed);
    let points: Vec<Vec<f64>> = (0..n).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect();
    if fast {
        let mut m = Figmn::new(cfg, &stds);
        let t = Instant::now();
        for p in &points {
            m.learn(p);
        }
        (t.elapsed().as_secs_f64() / n as f64, m.bytes_per_component())
    } else {
        let mut m = Igmn::new(cfg, &stds);
        let t = Instant::now();
        for p in &points {
            m.learn(p);
        }
        (t.elapsed().as_secs_f64() / n as f64, m.bytes_per_component())
    }
}

fn main() {
    let quick = quick_mode();
    // Sized so the whole sweep stays in a minutes-scale budget while the
    // cubic/quadratic split is unambiguous; quick mode shrinks the sweep
    // to a CI-smoke budget (and skips the exponent assertions — small D
    // is dominated by constant terms).
    // Full mode takes the FIGMN sweep to the paper's CIFAR-scale
    // D = 3072 (a ~38 MB packed triangle per component — every kernel sweep
    // streams from DRAM), so the fitted exponent now covers the regime
    // where the packed layout's bandwidth saving matters most. The
    // cubic IGMN baseline stays capped at 512; quick mode stays capped
    // for CI.
    let (dims_igmn, dims_figmn): (&[usize], &[usize]) = if quick {
        (&[8, 16, 32, 64], &[8, 16, 32, 64, 128])
    } else {
        (
            &[8, 16, 32, 64, 128, 256, 512],
            &[8, 16, 32, 64, 128, 256, 512, 1024, 2048, 3072],
        )
    };

    println!("S1 — per-point training cost vs D (K=1, β=0){}", if quick { " [quick]" } else { "" });
    let t = TablePrinter::new(&["D", "IGMN s/pt", "FIGMN s/pt", "ratio"], &[6, 14, 14, 10]);
    let mut igmn_pts: Vec<(f64, f64)> = Vec::new();
    let mut figmn_pts: Vec<(f64, f64)> = Vec::new();
    let mut rows: Vec<Json> = Vec::new();
    for &d in dims_figmn {
        let n_cap = if quick { 200 } else { 2000 };
        let n = (200_000 / d).clamp(20, n_cap); // keep each cell ~fixed work
        let (fast, bytes_per_comp) = per_point_seconds(d, n, true, 42);
        figmn_pts.push((d as f64, fast));
        // Packed-arena footprint next to the dense-equivalent payload,
        // so the layout's ~2× memory saving shows up in the JSON.
        let dense_equiv = ComponentStore::dense_equivalent_bytes(d);
        let mut row = vec![
            ("d", Json::from(d)),
            ("figmn_s_per_pt", fast.into()),
            ("bytes_per_component", bytes_per_comp.into()),
            ("dense_bytes_per_component", dense_equiv.into()),
        ];
        if dims_igmn.contains(&d) {
            let n_slow = (60 * 1024 / d.max(1)).clamp(10, if quick { 100 } else { 500 });
            let (slow, _) = per_point_seconds(d, n_slow, false, 42);
            igmn_pts.push((d as f64, slow));
            row.push(("igmn_s_per_pt", slow.into()));
            t.row(&[
                d.to_string(),
                format!("{slow:.3e}"),
                format!("{fast:.3e}"),
                format!("{:8.1}×", slow / fast),
            ]);
        } else {
            t.row(&[d.to_string(), "-".into(), format!("{fast:.3e}"), "-".into()]);
        }
        rows.push(Json::obj(row));
    }

    // Fit exponents on the asymptotic tail (D ≥ 64, where constant terms
    // stop mattering).
    let tail = |pts: &[(f64, f64)]| -> (Vec<f64>, Vec<f64>) {
        pts.iter().filter(|(d, _)| *d >= 64.0).map(|&(d, s)| (d, s)).unzip()
    };
    let (xi, yi) = tail(&igmn_pts);
    let (xf, yf) = tail(&figmn_pts);
    let p_igmn = if xi.len() >= 2 { fit_power_law(&xi, &yi) } else { f64::NAN };
    let p_figmn = if xf.len() >= 2 { fit_power_law(&xf, &yf) } else { f64::NAN };
    println!("\nfitted exponents (tail D ≥ 64):");
    println!("  IGMN : time ∝ D^{p_igmn:.2}   (paper claim: 3)");
    println!("  FIGMN: time ∝ D^{p_figmn:.2}   (paper claim: 2)");

    let payload = Json::obj(vec![
        ("bench", "scaling_dim".into()),
        ("quick", quick.into()),
        ("exponent_igmn", p_igmn.into()),
        ("exponent_figmn", p_figmn.into()),
        ("rows", Json::Arr(rows)),
    ]);
    match write_bench_json("scaling_dim", &payload) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }

    if quick {
        println!("scaling_dim done (quick mode: exponent assertions skipped)");
        return;
    }
    assert!(p_igmn > 2.5, "IGMN exponent {p_igmn} not cubic-ish");
    assert!(p_figmn < 2.5, "FIGMN exponent {p_figmn} not quadratic-ish");
    assert!(p_igmn - p_figmn > 0.6, "claimed complexity gap not observed");
    println!("scaling_dim OK — the paper's complexity separation holds");
}
