//! Complexity-scaling experiment (DESIGN.md row S1): per-point training
//! cost vs dimensionality for both variants, with fitted power-law
//! exponents — the direct empirical check of the paper's O(NKD³) →
//! O(NKD²) claim (its central contribution).
//!
//! Run: `cargo bench --bench scaling_dim`

use figmn::bench_support::{fit_power_law, TablePrinter};
use figmn::gmm::{Figmn, GmmConfig, Igmn, IncrementalMixture};
use figmn::rng::Pcg64;
use std::time::Instant;

fn per_point_seconds(dim: usize, n: usize, fast: bool, seed: u64) -> f64 {
    let cfg = GmmConfig::new(dim).with_delta(1.0).with_beta(0.0).without_pruning();
    let stds = vec![1.0; dim];
    let mut rng = Pcg64::seed(seed);
    let points: Vec<Vec<f64>> = (0..n).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect();
    if fast {
        let mut m = Figmn::new(cfg, &stds);
        let t = Instant::now();
        for p in &points {
            m.learn(p);
        }
        t.elapsed().as_secs_f64() / n as f64
    } else {
        let mut m = Igmn::new(cfg, &stds);
        let t = Instant::now();
        for p in &points {
            m.learn(p);
        }
        t.elapsed().as_secs_f64() / n as f64
    }
}

fn main() {
    // Sized so the whole sweep stays in a minutes-scale budget while the
    // cubic/quadratic split is unambiguous.
    let dims_igmn = [8usize, 16, 32, 64, 128, 256, 512];
    let dims_figmn = [8usize, 16, 32, 64, 128, 256, 512, 1024, 2048];

    println!("S1 — per-point training cost vs D (K=1, β=0)");
    let t = TablePrinter::new(&["D", "IGMN s/pt", "FIGMN s/pt", "ratio"], &[6, 14, 14, 10]);
    let mut igmn_pts: Vec<(f64, f64)> = Vec::new();
    let mut figmn_pts: Vec<(f64, f64)> = Vec::new();
    for &d in &dims_figmn {
        let n = (200_000 / d).clamp(20, 2000); // keep each cell ~fixed work
        let fast = per_point_seconds(d, n, true, 42);
        figmn_pts.push((d as f64, fast));
        if dims_igmn.contains(&d) {
            let n_slow = (60 * 1024 / d.max(1)).clamp(10, 500);
            let slow = per_point_seconds(d, n_slow, false, 42);
            igmn_pts.push((d as f64, slow));
            t.row(&[
                d.to_string(),
                format!("{slow:.3e}"),
                format!("{fast:.3e}"),
                format!("{:8.1}×", slow / fast),
            ]);
        } else {
            t.row(&[d.to_string(), "-".into(), format!("{fast:.3e}"), "-".into()]);
        }
    }

    // Fit exponents on the asymptotic tail (D ≥ 64, where constant terms
    // stop mattering).
    let tail = |pts: &[(f64, f64)]| -> (Vec<f64>, Vec<f64>) {
        pts.iter().filter(|(d, _)| *d >= 64.0).map(|&(d, s)| (d, s)).unzip()
    };
    let (xi, yi) = tail(&igmn_pts);
    let (xf, yf) = tail(&figmn_pts);
    let p_igmn = fit_power_law(&xi, &yi);
    let p_figmn = fit_power_law(&xf, &yf);
    println!("\nfitted exponents (tail D ≥ 64):");
    println!("  IGMN : time ∝ D^{p_igmn:.2}   (paper claim: 3)");
    println!("  FIGMN: time ∝ D^{p_figmn:.2}   (paper claim: 2)");
    assert!(p_igmn > 2.5, "IGMN exponent {p_igmn} not cubic-ish");
    assert!(p_figmn < 2.5, "FIGMN exponent {p_figmn} not quadratic-ish");
    assert!(p_igmn - p_figmn > 0.6, "claimed complexity gap not observed");
    println!("scaling_dim OK — the paper's complexity separation holds");
}
