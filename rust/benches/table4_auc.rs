//! Reproduces paper **Table 4 (Area Under ROC Curve)**: Neural Network
//! (dropout MLP), 1-NN, Naive Bayes, SVM, IGMN and FIGMN on the Table-4
//! dataset list (the 3072-D rows use the CIFAR-10b N=100 subset, as in
//! the paper), 2-fold cross-validation.
//!
//! FIGMN follows the paper's protocol: β = 0.001, δ tuned over
//! {0.01, 0.1, 1} by an inner 2-fold CV on the training fold. The paper's
//! own result — the IGMN and FIGMN columns are *identical* — is enforced
//! exactly on every dataset with D ≤ 64 and asserted; the two high-D rows
//! reuse the FIGMN scores for the IGMN column (marked `=`), since running
//! the O(D³) variant there adds hours and provably the same numbers.
//!
//! Run: `cargo bench --bench table4_auc`

use figmn::baselines::{Classifier, GaussianNaiveBayes, Knn, LinearSvm, Mlp, MlpConfig, SvmConfig};
use figmn::bench_support::gmm_eval::{run_classifier_cv, run_gmm_cv, Variant};
use figmn::bench_support::TablePrinter;
use figmn::data::synth;
use figmn::data::Dataset;
use figmn::eval::stratified_kfold;
use figmn::gmm::GmmConfig;
use figmn::stats::mean;

const TABLE4_DATASETS: [&str; 11] = [
    "breast-cancer",
    "CIFAR-10b",
    "german-credit",
    "pima-diabetes",
    "Glass",
    "ionosphere",
    "iris",
    "labor-neg-data",
    "MNIST",
    "soybean",
    "twospirals",
];

/// Component cap for the β = 0.001 runs: at D = 784/3072 a tiny δ makes
/// every point novel, growing K toward N/2 with O(K·D²) per point — the
/// paper handled this by shrinking the CIFAR subset ("to compensate for
/// the higher computational requirements of more Gaussian components");
/// we additionally cap K (identically for IGMN and FIGMN, so the
/// equality claim is untouched).
const MAX_COMPONENTS: usize = 32;

/// Tune δ ∈ {0.01, 0.1, 1} by inner 2-fold CV on the training fold
/// (paper §4), then return the fold AUCs with the winning δ.
fn figmn_cv_tuned(data: &Dataset, seed: u64) -> (Vec<f64>, f64) {
    let deltas = [0.01, 0.1, 1.0];
    let folds = stratified_kfold(&data.labels, data.n_classes, 2, seed);
    let mut aucs = Vec::new();
    let mut last_delta = deltas[0];
    for (tr, te) in folds {
        let train = data.subset(&tr);
        let test = data.subset(&te);
        // Inner tuning on the training fold only.
        let mut best = (f64::MIN, deltas[0]);
        for &d in &deltas {
            let cfg = GmmConfig::new(1)
                .with_delta(d)
                .with_beta(0.001)
                .with_max_components(MAX_COMPONENTS)
                .without_pruning();
            let inner = run_gmm_cv(&train, &cfg, Variant::Fast, seed ^ 0xABCD);
            let score = mean(&inner.iter().map(|f| f.auc(train.n_classes)).collect::<Vec<_>>());
            if score > best.0 {
                best = (score, d);
            }
        }
        last_delta = best.1;
        let cfg = GmmConfig::new(1)
            .with_delta(best.1)
            .with_beta(0.001)
            .with_max_components(MAX_COMPONENTS)
            .without_pruning();
        let fold = figmn::bench_support::gmm_eval::run_gmm_fold(&train, &test, &cfg, Variant::Fast);
        aucs.push(fold.auc(data.n_classes));
    }
    (aucs, last_delta)
}

/// Original-IGMN AUCs with a fixed δ (equality check path).
fn igmn_cv(data: &Dataset, delta: f64, seed: u64) -> Vec<f64> {
    let cfg = GmmConfig::new(1)
        .with_delta(delta)
        .with_beta(0.001)
        .with_max_components(MAX_COMPONENTS)
        .without_pruning();
    run_gmm_cv(data, &cfg, Variant::Original, seed)
        .iter()
        .map(|f| f.auc(data.n_classes))
        .collect()
}

fn main() {
    let seed = 42;
    let quick_mlp_epochs =
        if std::env::var("FIGMN_BENCH_FULL").map(|v| v == "1").unwrap_or(false) { 60 } else { 25 };

    println!("Table 4 — Area Under ROC Curve (2-fold CV; mean over folds)");
    let t = TablePrinter::new(
        &["dataset", "NeuralNet", "1-NN", "NaiveBayes", "SVM", "IGMN", "FIGMN"],
        &[16, 10, 10, 10, 10, 10, 10],
    );

    let mut col_means: Vec<Vec<f64>> = vec![Vec::new(); 6];
    for name in TABLE4_DATASETS {
        let spec = synth::spec(name).unwrap();
        let data = synth::generate(spec, seed);
        eprintln!("… {} (N={}, D={})", name, data.len(), data.dim());

        let auc_of = |folds: Vec<figmn::eval::FoldResult>| -> f64 {
            mean(&folds.iter().map(|f| f.auc(data.n_classes)).collect::<Vec<_>>())
        };

        let mlp = auc_of(run_classifier_cv(
            &data,
            &mut || {
                Box::new(Mlp::new(MlpConfig { epochs: quick_mlp_epochs, ..Default::default() }))
                    as Box<dyn Classifier>
            },
            seed,
        ));
        let knn = auc_of(run_classifier_cv(
            &data,
            &mut || Box::new(Knn::new(1)) as Box<dyn Classifier>,
            seed,
        ));
        let nb = auc_of(run_classifier_cv(
            &data,
            &mut || Box::new(GaussianNaiveBayes::new()) as Box<dyn Classifier>,
            seed,
        ));
        let svm = auc_of(run_classifier_cv(
            &data,
            &mut || Box::new(LinearSvm::new(SvmConfig::default())) as Box<dyn Classifier>,
            seed,
        ));

        let (figmn_aucs, tuned_delta) = figmn_cv_tuned(&data, seed);
        let figmn = mean(&figmn_aucs);
        // IGMN column: exact run + equality assertion where affordable.
        let (igmn, igmn_mark) = if data.dim() <= 64 {
            let igmn_aucs = igmn_cv(&data, tuned_delta, seed);
            // Same δ ⇒ identical AUC to FIGMN at that δ (paper's claim);
            // the tuned FIGMN column may differ only via per-fold tuning.
            let cfg = GmmConfig::new(1)
                .with_delta(tuned_delta)
                .with_beta(0.001)
                .with_max_components(MAX_COMPONENTS)
                .without_pruning();
            let fast_same = run_gmm_cv(&data, &cfg, Variant::Fast, seed)
                .iter()
                .map(|f| f.auc(data.n_classes))
                .collect::<Vec<_>>();
            for (a, b) in igmn_aucs.iter().zip(fast_same.iter()) {
                assert!((a - b).abs() < 1e-9, "{name}: IGMN≠FIGMN ({a} vs {b})");
            }
            (mean(&igmn_aucs), ' ')
        } else {
            (figmn, '=')
        };

        t.row(&[
            name.to_string(),
            format!("{mlp:.2}"),
            format!("{knn:.2}"),
            format!("{nb:.2}"),
            format!("{svm:.2}"),
            format!("{igmn:.2}{igmn_mark}"),
            format!("{figmn:.2}"),
        ]);
        for (c, v) in col_means.iter_mut().zip([mlp, knn, nb, svm, igmn, figmn]) {
            c.push(v);
        }
    }
    t.row(&[
        "Average".to_string(),
        format!("{:.2}", mean(&col_means[0])),
        format!("{:.2}", mean(&col_means[1])),
        format!("{:.2}", mean(&col_means[2])),
        format!("{:.2}", mean(&col_means[3])),
        format!("{:.2}", mean(&col_means[4])),
        format!("{:.2}", mean(&col_means[5])),
    ]);
    println!("\n(= : IGMN column reuses FIGMN scores on high-D rows; equality is asserted exactly on every D ≤ 64 dataset)");
}
