//! Runtime ablation (DESIGN.md row S3): batched scoring through the AOT
//! XLA artifact vs the native Rust implementation, at three shape
//! configs. Quantifies what the PJRT boundary costs (or saves) on the
//! inference path — the coordinator uses this to decide when the XLA
//! path is worth it.
//!
//! Requires `make artifacts`. Run: `cargo bench --bench runtime_overhead`

use figmn::bench_support::{time_reps, TablePrinter};
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture};
use figmn::rng::Pcg64;
use figmn::runtime::{PackedState, Runtime};
use figmn::stats::mean;

fn main() {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts/ — run `make artifacts` first; skipping");
        return;
    }
    let rt = Runtime::open(dir).expect("open artifacts");

    println!("S3 — batched scoring: XLA artifact vs native (per point, smaller is better)");
    let t = TablePrinter::new(
        &["config", "D", "K", "B", "native/pt", "xla/pt", "xla speedup"],
        &[12, 6, 5, 5, 12, 12, 12],
    );

    for meta in rt.manifest().artifacts().iter().filter(|a| {
        matches!(a.kind, figmn::runtime::ArtifactKind::Score)
    }) {
        let (d, k, b) = (meta.dim, meta.capacity, meta.batch);
        // Train a native model at this joint shape, filling ~K components.
        let cfg = GmmConfig::new(d)
            .with_delta(0.5)
            .with_beta(0.2)
            .with_max_components(k)
            .without_pruning();
        let stds = vec![1.0; d];
        let mut model = Figmn::new(cfg, &stds);
        let mut rng = Pcg64::seed(9);
        for i in 0..200 {
            let c = (i % 4) as f64 * 5.0;
            let x: Vec<f64> = (0..d).map(|_| c + rng.normal()).collect();
            model.learn(&x);
        }
        let state = PackedState::from_figmn(&model, k);

        // A batch of query points.
        let queries: Vec<Vec<f64>> =
            (0..b).map(|_| (0..d).map(|_| rng.normal() * 3.0).collect()).collect();
        let mut xs = Vec::with_capacity(b * d);
        for q in &queries {
            xs.extend(q.iter().map(|&v| v as f32));
        }

        // Native batched scoring.
        let native = time_reps(20, || {
            for q in &queries {
                let _ = model.posteriors(q);
            }
        });

        // XLA batched scoring (compile once, then steady-state).
        let exec = rt.score_exec(&meta.config).expect("score exec");
        let _ = exec.score(&xs, &state).expect("warmup");
        let xla = time_reps(20, || {
            let _ = exec.score(&xs, &state).unwrap();
        });

        let native_pt = mean(&native) / b as f64;
        let xla_pt = mean(&xla) / b as f64;
        t.row(&[
            meta.config.clone(),
            d.to_string(),
            k.to_string(),
            b.to_string(),
            format!("{native_pt:.3e}"),
            format!("{xla_pt:.3e}"),
            format!("{:8.2}×", native_pt / xla_pt),
        ]);
    }
    println!("\n(native = f64 per-point loop; xla = f32 B-batch through PJRT incl. literal marshalling)");
}
