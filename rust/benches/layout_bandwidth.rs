//! Layout micro-benchmark: dense array-of-structs vs the packed
//! flat-arena component layout, on the paper's two hot kernels — the
//! `Λ·v` quadratic form (Eq. 22) and the fused Sherman–Morrison update
//! (Eqs. 20–21/25–26). Both are memory-bandwidth-bound at scale, so the
//! packed layout's ~2× fewer bytes per component is the quantity under
//! test, alongside the bit-identity gate (packed sweeps must reproduce
//! the dense trajectory exactly).
//!
//! Run: `cargo bench --bench layout_bandwidth`
//! Quick (CI smoke): `FIGMN_BENCH_QUICK=1 cargo bench --bench layout_bandwidth`
//! Writes `BENCH_layout_bandwidth.json` with dense-vs-packed throughput
//! and bytes-per-component on the `scaling_dim` grid D ∈ {16, 64, 128}.

use figmn::bench_support::{quick_mode, write_bench_json, TablePrinter};
use figmn::gmm::ComponentStore;
use figmn::json::Json;
use figmn::linalg::packed;
use figmn::linalg::rank_one::{figmn_fused_update, figmn_fused_update_packed};
use figmn::linalg::Matrix;
use figmn::rng::Pcg64;
use std::time::Instant;

/// Dense mirror of one component (the pre-store array-of-structs shape).
struct DenseComp {
    mean: Vec<f64>,
    lambda: Matrix,
    log_det: f64,
}

/// Packed flat arenas (the ComponentStore shape, inlined so the bench
/// depends only on the public linalg kernels).
struct PackedArenas {
    means: Vec<f64>,
    mats: Vec<f64>,
    log_dets: Vec<f64>,
}

fn build(d: usize, k: usize, seed: u64) -> (Vec<DenseComp>, PackedArenas) {
    let mut rng = Pcg64::seed(seed);
    let tri = packed::packed_len(d);
    let mut dense = Vec::with_capacity(k);
    let mut arenas = PackedArenas {
        means: Vec::with_capacity(k * d),
        mats: Vec::with_capacity(k * tri),
        log_dets: Vec::with_capacity(k),
    };
    for _ in 0..k {
        // Diagonally-dominant SPD precision: diag 2+|n|, small off-diag.
        let mut lam = Matrix::zeros(d, d);
        for i in 0..d {
            lam[(i, i)] = 2.0 + rng.uniform();
        }
        for i in 0..d {
            for j in i + 1..d {
                let v = rng.normal() * 0.01;
                lam[(i, j)] = v;
                lam[(j, i)] = v;
            }
        }
        let mean: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let log_det = rng.normal() * 0.1;
        arenas.means.extend_from_slice(&mean);
        arenas.mats.extend(packed::pack_symmetric(&lam));
        arenas.log_dets.push(log_det);
        dense.push(DenseComp { mean, lambda: lam, log_det });
    }
    (dense, arenas)
}

/// One learn-like sweep over all K components in the dense layout:
/// distance pass (quad_form_with) + fused update per component.
fn dense_sweep(comps: &mut [DenseComp], x: &[f64], w: &mut [f64], e: &mut [f64], omega: f64) {
    for c in comps.iter_mut() {
        for ((ei, &xi), &mi) in e.iter_mut().zip(x.iter()).zip(c.mean.iter()) {
            *ei = xi - mi;
        }
        let q = c.lambda.quad_form_with(e, w);
        if let Some(r) = figmn_fused_update(&mut c.lambda, w, q, omega, c.log_det) {
            c.log_det = r.log_det;
        }
    }
}

/// The same sweep over the packed flat arenas.
fn packed_sweep(
    arenas: &mut PackedArenas,
    d: usize,
    x: &[f64],
    w: &mut [f64],
    e: &mut [f64],
    omega: f64,
) {
    let tri = packed::packed_len(d);
    let k = arenas.log_dets.len();
    for j in 0..k {
        let mean = &arenas.means[j * d..(j + 1) * d];
        for ((ei, &xi), &mi) in e.iter_mut().zip(x.iter()).zip(mean.iter()) {
            *ei = xi - mi;
        }
        let mat = &mut arenas.mats[j * tri..(j + 1) * tri];
        let q = packed::quad_form_with(mat, d, e, w);
        if let Some(r) = figmn_fused_update_packed(mat, d, w, q, omega, arenas.log_dets[j]) {
            arenas.log_dets[j] = r.log_det;
        }
    }
}

fn main() {
    let quick = quick_mode();
    let dims: &[usize] = &[16, 64, 128];
    let k = if quick { 32 } else { 128 };
    println!(
        "layout_bandwidth — dense AoS vs packed SoA, K={k}{}",
        if quick { " [quick]" } else { "" }
    );
    let t = TablePrinter::new(
        &["D", "dense pts/s", "packed pts/s", "speedup", "dense B/comp", "packed B/comp"],
        &[6, 14, 14, 9, 13, 13],
    );
    let mut rows: Vec<Json> = Vec::new();
    for &d in dims {
        let points = if quick { 200_000 / (d * d) + 20 } else { 4_000_000 / (d * d) + 50 };
        let (mut dense, mut arenas) = build(d, k, 7);
        let mut rng = Pcg64::seed(11);
        let xs: Vec<Vec<f64>> =
            (0..points).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        let omega = 0.01;
        let mut w = vec![0.0; d];
        let mut e = vec![0.0; d];

        let t0 = Instant::now();
        for x in &xs {
            dense_sweep(&mut dense, x, &mut w, &mut e, omega);
        }
        let dense_pts = points as f64 / t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        for x in &xs {
            packed_sweep(&mut arenas, d, x, &mut w, &mut e, omega);
        }
        let packed_pts = points as f64 / t0.elapsed().as_secs_f64();

        // Bit-identity gate: after identical update streams, every
        // packed row must equal the dense matrix's upper triangle and
        // every log-det must match exactly.
        let tri = packed::packed_len(d);
        for (j, c) in dense.iter().enumerate() {
            assert_eq!(
                packed::pack_symmetric(&c.lambda),
                arenas.mats[j * tri..(j + 1) * tri].to_vec(),
                "D={d}: packed trajectory diverged from dense at component {j}"
            );
            assert!(
                c.log_det.to_bits() == arenas.log_dets[j].to_bits(),
                "D={d}: log-det bits diverged at component {j}"
            );
        }

        // Payload bytes per component in each layout, from the store's
        // own accounting (one source of truth with `model_bytes`).
        let dense_bytes = ComponentStore::dense_equivalent_bytes(d);
        let packed_bytes = ComponentStore::new(d).bytes_per_component();
        t.row(&[
            d.to_string(),
            format!("{dense_pts:.3e}"),
            format!("{packed_pts:.3e}"),
            format!("{:6.2}×", packed_pts / dense_pts),
            dense_bytes.to_string(),
            packed_bytes.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("d", Json::from(d)),
            ("k", Json::from(k)),
            ("points", Json::from(points)),
            ("dense_pts_per_s", dense_pts.into()),
            ("packed_pts_per_s", packed_pts.into()),
            ("speedup", (packed_pts / dense_pts).into()),
            ("dense_bytes_per_component", dense_bytes.into()),
            ("packed_bytes_per_component", packed_bytes.into()),
        ]));
    }

    let payload = Json::obj(vec![
        ("bench", "layout_bandwidth".into()),
        ("quick", quick.into()),
        ("rows", Json::Arr(rows)),
    ]);
    match write_bench_json("layout_bandwidth", &payload) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
    println!("layout_bandwidth OK — packed trajectories bit-identical to dense");
}
