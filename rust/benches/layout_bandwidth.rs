//! Layout micro-benchmark: dense array-of-structs vs the packed
//! flat-arena component layout, on the paper's two hot kernels — the
//! `Λ·v` quadratic form (Eq. 22) and the fused Sherman–Morrison update
//! (Eqs. 20–21/25–26). Both are memory-bandwidth-bound at scale, so the
//! packed layout's ~2× fewer bytes per component is the quantity under
//! test, alongside the bit-identity gate (packed sweeps must reproduce
//! the dense trajectory exactly).
//!
//! Since the dual-mode kernels landed, the bench also records the
//! **strict-vs-fast** series on the packed layout (D ∈ {16, 64, 128,
//! 1024, 3072} in full mode — 3072 is the paper's CIFAR scale, where a
//! packed triangle alone is ~38 MB and every sweep runs from DRAM): same
//! sweeps, `KernelMode::Fast`'s blocked auto-vectorizable loops against
//! `Strict`'s scalar reference, with a tolerance gate (fast
//! trajectories must track strict ones) and a full-mode ≥1.5×
//! throughput assertion at D ≥ 64.
//!
//! The **blocked multi-query** series times the serving read path's
//! tentpole: per-point `quad_form` (each query re-streams the packed
//! triangle) against `quad_form_multi`/`quad_form_multi_fast` at
//! B ∈ {1, 8, 32}, with bitwise gates (blocking must not change any
//! query's value) and a full-mode ≥2× assertion for the strict blocked
//! kernel at B = 32, 256 ≤ D ≤ 1024. A reservation probe records that
//! `ComponentStore` arenas stay at fixed base addresses across creates
//! when `max_components` is set.
//!
//! The **f32 replica** series times the replica tier's kernel
//! (`quad_form_multi_f32`, at the detected SIMD tier) against the f64
//! blocked fast kernel at B = 32: half the streamed bytes per sweep,
//! gated to the replica contract's 1e-3 relative tolerance, with a
//! full-mode ≥1.5× assertion at D ≥ 1024 where the f64 sweep runs from
//! DRAM.
//!
//! Run: `cargo bench --bench layout_bandwidth`
//! Quick (CI smoke): `FIGMN_BENCH_QUICK=1 cargo bench --bench layout_bandwidth`
//! Writes `BENCH_layout_bandwidth.json` (dense-vs-packed rows, the
//! strict-vs-fast series, and the reservation record) at the current
//! directory — `scripts/bench_smoke.sh` runs it from the repo root.

use figmn::bench_support::{quick_mode, write_bench_json, TablePrinter};
use figmn::gmm::{ComponentStore, Figmn, GmmConfig, IncrementalMixture, KernelMode};
use figmn::json::Json;
use figmn::linalg::packed;
use figmn::linalg::rank_one::{
    figmn_fused_update, figmn_fused_update_packed, figmn_fused_update_packed_mode,
};
use figmn::linalg::Matrix;
use figmn::rng::Pcg64;
use std::time::Instant;

/// Dense mirror of one component (the pre-store array-of-structs shape).
struct DenseComp {
    mean: Vec<f64>,
    lambda: Matrix,
    log_det: f64,
}

/// Packed flat arenas (the ComponentStore shape, inlined so the bench
/// depends only on the public linalg kernels).
struct PackedArenas {
    means: Vec<f64>,
    mats: Vec<f64>,
    log_dets: Vec<f64>,
}

fn build(d: usize, k: usize, seed: u64) -> (Vec<DenseComp>, PackedArenas) {
    let mut rng = Pcg64::seed(seed);
    let tri = packed::packed_len(d);
    let mut dense = Vec::with_capacity(k);
    let mut arenas = PackedArenas {
        means: Vec::with_capacity(k * d),
        mats: Vec::with_capacity(k * tri),
        log_dets: Vec::with_capacity(k),
    };
    for _ in 0..k {
        // Diagonally-dominant SPD precision: diag 2+|n|, small off-diag.
        let mut lam = Matrix::zeros(d, d);
        for i in 0..d {
            lam[(i, i)] = 2.0 + rng.uniform();
        }
        for i in 0..d {
            for j in i + 1..d {
                let v = rng.normal() * 0.01;
                lam[(i, j)] = v;
                lam[(j, i)] = v;
            }
        }
        let mean: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let log_det = rng.normal() * 0.1;
        arenas.means.extend_from_slice(&mean);
        arenas.mats.extend(packed::pack_symmetric(&lam));
        arenas.log_dets.push(log_det);
        dense.push(DenseComp { mean, lambda: lam, log_det });
    }
    (dense, arenas)
}

/// Packed-only builder for the large-D series (the dense mirror of
/// [`build`] would cost `K·D²` doubles — ~75 MB per component at
/// D = 3072 — and those series never touch it). Same diagonally-
/// dominant SPD shape, written straight into packed storage.
fn build_packed(d: usize, k: usize, seed: u64) -> PackedArenas {
    let mut rng = Pcg64::seed(seed);
    let tri = packed::packed_len(d);
    let mut arenas = PackedArenas {
        means: Vec::with_capacity(k * d),
        mats: Vec::with_capacity(k * tri),
        log_dets: Vec::with_capacity(k),
    };
    for _ in 0..k {
        for i in 0..d {
            arenas.mats.push(2.0 + rng.uniform()); // diagonal (i, i)
            for _ in i + 1..d {
                arenas.mats.push(rng.normal() * 0.01 / (d as f64)); // (i, j>i)
            }
        }
        arenas.means.extend((0..d).map(|_| rng.normal()));
        arenas.log_dets.push(rng.normal() * 0.1);
    }
    arenas
}

/// One learn-like sweep over all K components in the dense layout:
/// distance pass (quad_form_with) + fused update per component.
fn dense_sweep(comps: &mut [DenseComp], x: &[f64], w: &mut [f64], e: &mut [f64], omega: f64) {
    for c in comps.iter_mut() {
        for ((ei, &xi), &mi) in e.iter_mut().zip(x.iter()).zip(c.mean.iter()) {
            *ei = xi - mi;
        }
        let q = c.lambda.quad_form_with(e, w);
        if let Some(r) = figmn_fused_update(&mut c.lambda, w, q, omega, c.log_det) {
            c.log_det = r.log_det;
        }
    }
}

/// The same sweep over the packed flat arenas.
fn packed_sweep(
    arenas: &mut PackedArenas,
    d: usize,
    x: &[f64],
    w: &mut [f64],
    e: &mut [f64],
    omega: f64,
) {
    let tri = packed::packed_len(d);
    let k = arenas.log_dets.len();
    for j in 0..k {
        let mean = &arenas.means[j * d..(j + 1) * d];
        for ((ei, &xi), &mi) in e.iter_mut().zip(x.iter()).zip(mean.iter()) {
            *ei = xi - mi;
        }
        let mat = &mut arenas.mats[j * tri..(j + 1) * tri];
        let q = packed::quad_form_with(mat, d, e, w);
        if let Some(r) = figmn_fused_update_packed(mat, d, w, q, omega, arenas.log_dets[j]) {
            arenas.log_dets[j] = r.log_det;
        }
    }
}

/// The packed sweep with a selectable kernel mode (the strict arm is
/// the same instruction sequence as [`packed_sweep`]).
fn packed_sweep_mode(
    arenas: &mut PackedArenas,
    d: usize,
    x: &[f64],
    w: &mut [f64],
    e: &mut [f64],
    omega: f64,
    mode: KernelMode,
) {
    let tri = packed::packed_len(d);
    let k = arenas.log_dets.len();
    for j in 0..k {
        let mean = &arenas.means[j * d..(j + 1) * d];
        for ((ei, &xi), &mi) in e.iter_mut().zip(x.iter()).zip(mean.iter()) {
            *ei = xi - mi;
        }
        let mat = &mut arenas.mats[j * tri..(j + 1) * tri];
        let q = packed::quad_form_with_mode(mat, d, e, w, mode);
        if let Some(r) =
            figmn_fused_update_packed_mode(mat, d, w, q, omega, arenas.log_dets[j], mode)
        {
            arenas.log_dets[j] = r.log_det;
        }
    }
}

/// Reservation probe: drive creates through the public model API and
/// record whether the arena base address moved. With `max_components`
/// reserved the base must stay put; without a bound it is allowed (and
/// expected, for enough creates) to move.
fn reservation_probe(reserve: bool) -> (bool, usize) {
    let rows = 128;
    let d = 4;
    let mut cfg = GmmConfig::new(d).with_beta(0.5).with_delta(0.001).without_pruning();
    if reserve {
        cfg = cfg.with_max_components(rows);
    }
    let mut m = Figmn::new(cfg, &[1.0; 4]);
    m.learn(&[0.0; 4]);
    let base = m.store().mean(0).as_ptr();
    for i in 1..rows {
        // Every point is far from everything seen → a create per point.
        m.learn(&[i as f64 * 1e4, 0.0, 0.0, 0.0]);
    }
    assert_eq!(m.num_components(), rows, "probe stream must create {rows} components");
    (!std::ptr::eq(base, m.store().mean(0).as_ptr()), m.store().capacity_rows())
}

fn main() {
    let quick = quick_mode();
    let dims: &[usize] = &[16, 64, 128];
    let k = if quick { 32 } else { 128 };
    println!(
        "layout_bandwidth — dense AoS vs packed SoA, K={k}{}",
        if quick { " [quick]" } else { "" }
    );
    let t = TablePrinter::new(
        &["D", "dense pts/s", "packed pts/s", "speedup", "dense B/comp", "packed B/comp"],
        &[6, 14, 14, 9, 13, 13],
    );
    let mut rows: Vec<Json> = Vec::new();
    for &d in dims {
        let points = if quick { 200_000 / (d * d) + 20 } else { 4_000_000 / (d * d) + 50 };
        let (mut dense, mut arenas) = build(d, k, 7);
        let mut rng = Pcg64::seed(11);
        let xs: Vec<Vec<f64>> =
            (0..points).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        let omega = 0.01;
        let mut w = vec![0.0; d];
        let mut e = vec![0.0; d];

        let t0 = Instant::now();
        for x in &xs {
            dense_sweep(&mut dense, x, &mut w, &mut e, omega);
        }
        let dense_pts = points as f64 / t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        for x in &xs {
            packed_sweep(&mut arenas, d, x, &mut w, &mut e, omega);
        }
        let packed_pts = points as f64 / t0.elapsed().as_secs_f64();

        // Bit-identity gate: after identical update streams, every
        // packed row must equal the dense matrix's upper triangle and
        // every log-det must match exactly.
        let tri = packed::packed_len(d);
        for (j, c) in dense.iter().enumerate() {
            assert_eq!(
                packed::pack_symmetric(&c.lambda),
                arenas.mats[j * tri..(j + 1) * tri].to_vec(),
                "D={d}: packed trajectory diverged from dense at component {j}"
            );
            assert!(
                c.log_det.to_bits() == arenas.log_dets[j].to_bits(),
                "D={d}: log-det bits diverged at component {j}"
            );
        }

        // Payload bytes per component in each layout, from the store's
        // own accounting (one source of truth with `model_bytes`).
        let dense_bytes = ComponentStore::dense_equivalent_bytes(d);
        let packed_bytes = ComponentStore::new(d).bytes_per_component();
        t.row(&[
            d.to_string(),
            format!("{dense_pts:.3e}"),
            format!("{packed_pts:.3e}"),
            format!("{:6.2}×", packed_pts / dense_pts),
            dense_bytes.to_string(),
            packed_bytes.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("d", Json::from(d)),
            ("k", Json::from(k)),
            ("points", Json::from(points)),
            ("dense_pts_per_s", dense_pts.into()),
            ("packed_pts_per_s", packed_pts.into()),
            ("speedup", (packed_pts / dense_pts).into()),
            ("dense_bytes_per_component", dense_bytes.into()),
            ("packed_bytes_per_component", packed_bytes.into()),
        ]));
    }

    // ---- strict vs fast kernel modes on the packed layout -----------
    // Full mode now reaches the paper's CIFAR-scale D = 3072, where one
    // packed triangle alone is ~38 MB — far past every cache level, so the
    // series records where the strict/fast sweeps saturate bandwidth.
    let mode_dims: &[usize] = if quick { &[16, 64] } else { &[16, 64, 128, 1024, 3072] };
    println!("\nstrict vs fast packed kernels{}", if quick { " [quick]" } else { "" });
    let t2 = TablePrinter::new(
        &["D", "K", "strict pts/s", "fast pts/s", "speedup"],
        &[6, 5, 14, 14, 9],
    );
    let mut mode_rows: Vec<Json> = Vec::new();
    for &d in mode_dims {
        // Shrink K as D grows so the full-mode arenas stay bounded
        // (~130 MB at D=1024; ~300 MB for the two D=3072 arenas).
        let km = if d >= 2048 {
            4
        } else if quick || d >= 512 {
            16
        } else {
            128
        };
        let points = if quick { 200_000 / (d * d) + 20 } else { 4_000_000 / (d * d) + 50 };
        let mut rng = Pcg64::seed(23);
        let xs: Vec<Vec<f64>> =
            (0..points).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        let omega = 0.01;
        let mut w = vec![0.0; d];
        let mut e = vec![0.0; d];

        let mut strict_arenas = build_packed(d, km, 13);
        let mut fast_arenas = PackedArenas {
            means: strict_arenas.means.clone(),
            mats: strict_arenas.mats.clone(),
            log_dets: strict_arenas.log_dets.clone(),
        };

        let t0 = Instant::now();
        for x in &xs {
            packed_sweep_mode(&mut strict_arenas, d, x, &mut w, &mut e, omega, KernelMode::Strict);
        }
        let strict_pts = points as f64 / t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        for x in &xs {
            packed_sweep_mode(&mut fast_arenas, d, x, &mut w, &mut e, omega, KernelMode::Fast);
        }
        let fast_pts = points as f64 / t0.elapsed().as_secs_f64();
        let speedup = fast_pts / strict_pts;

        // Tolerance gate: after identical update streams, the fast
        // trajectory must track the strict one (same math, blocked
        // summation order).
        let tri = packed::packed_len(d);
        for j in 0..km {
            let s_row = &strict_arenas.mats[j * tri..(j + 1) * tri];
            let f_row = &fast_arenas.mats[j * tri..(j + 1) * tri];
            let scale = s_row.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            for (i, (a, b)) in s_row.iter().zip(f_row.iter()).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-6 * scale,
                    "D={d}: fast trajectory diverged at component {j} entry {i} ({a} vs {b})"
                );
            }
            let (ls, lf) = (strict_arenas.log_dets[j], fast_arenas.log_dets[j]);
            assert!(
                (ls - lf).abs() <= 1e-6 * (1.0 + ls.abs()),
                "D={d}: log-det diverged at component {j} ({ls} vs {lf})"
            );
        }
        // No floor at D=3072: that dim exists to *record* where both
        // modes hit the bandwidth ceiling (the fast speedup is allowed
        // to collapse there), mirroring the blocked series' 256..=1024
        // assert range below.
        if !quick && (64..=1024).contains(&d) {
            assert!(
                speedup >= 1.5,
                "D={d}: fast kernels must be ≥1.5× strict, got {speedup:.2}×"
            );
        }

        t2.row(&[
            d.to_string(),
            km.to_string(),
            format!("{strict_pts:.3e}"),
            format!("{fast_pts:.3e}"),
            format!("{speedup:6.2}×"),
        ]);
        mode_rows.push(Json::obj(vec![
            ("d", Json::from(d)),
            ("k", Json::from(km)),
            ("points", Json::from(points)),
            ("strict_pts_per_s", strict_pts.into()),
            ("fast_pts_per_s", fast_pts.into()),
            ("fast_speedup", speedup.into()),
        ]));
    }

    // ---- blocked multi-query scoring kernels ------------------------
    // The serving read path's tentpole: per-point scoring re-streams
    // every packed triangle once per query; the multi-query kernels
    // stream each packed row once per B-query block. This series times
    // both, per mode, at B ∈ {1, 8, 32} — and extends to the paper's
    // CIFAR-scale D = 3072 in full mode to record where the blocked
    // sweep, too, saturates bandwidth.
    let blk_dims: &[usize] = if quick { &[16, 64] } else { &[64, 256, 1024, 3072] };
    let tag = if quick { " [quick]" } else { "" };
    println!("\nblocked multi-query vs per-point scoring kernels{tag}");
    let t3 = TablePrinter::new(
        &[
            "D",
            "K",
            "B",
            "strict pp q/s",
            "strict blk q/s",
            "spd",
            "fast pp q/s",
            "fast blk q/s",
            "spd",
        ],
        &[6, 5, 4, 14, 14, 7, 14, 14, 7],
    );
    let mut blk_rows: Vec<Json> = Vec::new();
    let mut min_blk_speedup_mid_d = f64::INFINITY;
    for &d in blk_dims {
        let kb = if d >= 2048 {
            4
        } else if d >= 512 {
            16
        } else if quick {
            32
        } else {
            64
        };
        let arenas = build_packed(d, kb, 31);
        let tri = packed::packed_len(d);
        let nq = if quick { 32 } else { (64_000_000 / (kb * d * d)).clamp(32, 256) };
        let mut rng = Pcg64::seed(37);
        // Residual blocks directly (the mean subtraction is O(B·D) and
        // identical on both paths — this series times the kernels).
        let es: Vec<f64> = (0..nq * d).map(|_| rng.normal()).collect();
        let mut w1 = vec![0.0; d];
        let mut wide = vec![0.0; 32 * d];
        let mut out = vec![0.0; 32];

        // Per-point and blocked sweeps per mode; bitwise gates prove
        // blocking changes no query's value.
        let t0 = Instant::now();
        let mut sink = 0.0;
        for q in 0..nq {
            let x = &es[q * d..(q + 1) * d];
            for j in 0..kb {
                sink += packed::quad_form(&arenas.mats[j * tri..(j + 1) * tri], d, x);
            }
        }
        let strict_pp = nq as f64 / t0.elapsed().as_secs_f64();
        assert!(sink.is_finite());

        let mut strict_blk_rates = Vec::new();
        let mut fast_blk_rates = Vec::new();
        for &bsz in &[1usize, 8, 32] {
            let t0 = Instant::now();
            let mut check = 0.0;
            for qs in (0..nq).step_by(bsz) {
                let b = bsz.min(nq - qs);
                let block = &es[qs * d..(qs + b) * d];
                for j in 0..kb {
                    packed::quad_form_multi(
                        &arenas.mats[j * tri..(j + 1) * tri],
                        d,
                        block,
                        b,
                        &mut out[..b],
                    );
                    check += out[..b].iter().sum::<f64>();
                }
            }
            strict_blk_rates.push((bsz, nq as f64 / t0.elapsed().as_secs_f64()));
            assert!(check.is_finite());
        }
        // Bitwise gate (strict): one block's results equal the scalar kernel.
        {
            let b = 32.min(nq);
            packed::quad_form_multi(&arenas.mats[..tri], d, &es[..b * d], b, &mut out[..b]);
            for (q, o) in out[..b].iter().enumerate() {
                let expect = packed::quad_form(&arenas.mats[..tri], d, &es[q * d..(q + 1) * d]);
                assert!(
                    o.to_bits() == expect.to_bits(),
                    "D={d}: strict blocked bits diverged at query {q}"
                );
            }
        }

        let t0 = Instant::now();
        let mut sink = 0.0;
        for q in 0..nq {
            let x = &es[q * d..(q + 1) * d];
            for j in 0..kb {
                sink += packed::quad_form_with_fast(
                    &arenas.mats[j * tri..(j + 1) * tri],
                    d,
                    x,
                    &mut w1,
                );
            }
        }
        let fast_pp = nq as f64 / t0.elapsed().as_secs_f64();
        assert!(sink.is_finite());

        for &bsz in &[1usize, 8, 32] {
            let t0 = Instant::now();
            let mut check = 0.0;
            for qs in (0..nq).step_by(bsz) {
                let b = bsz.min(nq - qs);
                let block = &es[qs * d..(qs + b) * d];
                for j in 0..kb {
                    packed::quad_form_multi_fast(
                        &arenas.mats[j * tri..(j + 1) * tri],
                        d,
                        block,
                        b,
                        &mut wide[..b * d],
                        &mut out[..b],
                    );
                    check += out[..b].iter().sum::<f64>();
                }
            }
            fast_blk_rates.push((bsz, nq as f64 / t0.elapsed().as_secs_f64()));
            assert!(check.is_finite());
        }
        // Bitwise gate (fast): blocked equals the per-point fast kernel.
        {
            let b = 32.min(nq);
            packed::quad_form_multi_fast(
                &arenas.mats[..tri],
                d,
                &es[..b * d],
                b,
                &mut wide[..b * d],
                &mut out[..b],
            );
            for (q, o) in out[..b].iter().enumerate() {
                let expect = packed::quad_form_with_fast(
                    &arenas.mats[..tri],
                    d,
                    &es[q * d..(q + 1) * d],
                    &mut w1,
                );
                assert!(
                    o.to_bits() == expect.to_bits(),
                    "D={d}: fast blocked bits diverged at query {q}"
                );
            }
        }

        for (&(bsz, s_rate), &(_, f_rate)) in strict_blk_rates.iter().zip(fast_blk_rates.iter()) {
            let s_spd = s_rate / strict_pp;
            let f_spd = f_rate / fast_pp;
            if bsz == 32 && (256..=1024).contains(&d) {
                min_blk_speedup_mid_d = min_blk_speedup_mid_d.min(s_spd);
            }
            t3.row(&[
                d.to_string(),
                kb.to_string(),
                bsz.to_string(),
                format!("{strict_pp:.3e}"),
                format!("{s_rate:.3e}"),
                format!("{s_spd:5.2}×"),
                format!("{fast_pp:.3e}"),
                format!("{f_rate:.3e}"),
                format!("{f_spd:5.2}×"),
            ]);
            blk_rows.push(Json::obj(vec![
                ("d", Json::from(d)),
                ("k", Json::from(kb)),
                ("b", Json::from(bsz)),
                ("strict_per_point_q_per_s", strict_pp.into()),
                ("strict_blocked_q_per_s", s_rate.into()),
                ("strict_blocked_speedup", s_spd.into()),
                ("fast_per_point_q_per_s", fast_pp.into()),
                ("fast_blocked_q_per_s", f_rate.into()),
                ("fast_blocked_speedup", f_spd.into()),
            ]));
        }
    }
    if !quick {
        assert!(
            min_blk_speedup_mid_d >= 2.0,
            "strict blocked kernels at B=32 must be ≥2× per-point for 256 ≤ D ≤ 1024, \
             got {min_blk_speedup_mid_d:.2}×"
        );
    }

    // ---- f32 replica multi-query kernels ----------------------------
    // The replica tier's bet: the blocked sweep is bandwidth-bound at
    // large D, so streaming f32 triangles (half the bytes) should
    // approach 2× the f64 blocked rate where the f64 sweep runs from
    // DRAM. Tolerance gate: every f32 quadratic form within 1e-3
    // relative of the f64 fast kernel (the replica contract's default,
    // with orders of magnitude of headroom over f32's intrinsic error).
    let tier = packed::simd_tier();
    let rep_dims: &[usize] = if quick { &[16, 64] } else { &[64, 256, 1024, 3072] };
    println!("\nf32 replica vs f64 blocked scoring kernels{tag} (simd tier: {tier})");
    let t4 = TablePrinter::new(
        &["D", "K", "B", "f64 blk q/s", "f32 blk q/s", "spd"],
        &[6, 5, 4, 14, 14, 7],
    );
    let mut rep_rows: Vec<Json> = Vec::new();
    let mut min_rep_speedup_large_d = f64::INFINITY;
    for &d in rep_dims {
        let kb = if d >= 2048 {
            4
        } else if d >= 512 {
            16
        } else if quick {
            32
        } else {
            64
        };
        let arenas = build_packed(d, kb, 41);
        let tri = packed::packed_len(d);
        let nq = if quick { 32 } else { (64_000_000 / (kb * d * d)).clamp(32, 256) };
        let mut rng = Pcg64::seed(43);
        let es: Vec<f64> = (0..nq * d).map(|_| rng.normal()).collect();
        // Narrow once, off the timed path — exactly what snapshot
        // publish does for the arenas and the block loader for queries.
        let mats32: Vec<f32> = arenas.mats.iter().map(|&v| v as f32).collect();
        let es32: Vec<f32> = es.iter().map(|&v| v as f32).collect();
        let mut wide = vec![0.0; 32 * d];
        let mut wide32 = vec![0.0f32; 32 * d];
        let mut out = vec![0.0; 32];
        let bsz = 32usize;

        let t0 = Instant::now();
        let mut check = 0.0;
        for qs in (0..nq).step_by(bsz) {
            let b = bsz.min(nq - qs);
            let block = &es[qs * d..(qs + b) * d];
            for j in 0..kb {
                packed::quad_form_multi_fast(
                    &arenas.mats[j * tri..(j + 1) * tri],
                    d,
                    block,
                    b,
                    &mut wide[..b * d],
                    &mut out[..b],
                );
                check += out[..b].iter().sum::<f64>();
            }
        }
        let f64_rate = nq as f64 / t0.elapsed().as_secs_f64();
        assert!(check.is_finite());

        let t0 = Instant::now();
        let mut check = 0.0;
        for qs in (0..nq).step_by(bsz) {
            let b = bsz.min(nq - qs);
            let block = &es32[qs * d..(qs + b) * d];
            for j in 0..kb {
                packed::quad_form_multi_f32(
                    &mats32[j * tri..(j + 1) * tri],
                    d,
                    block,
                    b,
                    &mut wide32[..b * d],
                    &mut out[..b],
                );
                check += out[..b].iter().sum::<f64>();
            }
        }
        let f32_rate = nq as f64 / t0.elapsed().as_secs_f64();
        assert!(check.is_finite());
        let speedup = f32_rate / f64_rate;

        // Tolerance gate: one block against the f64 fast kernel, every
        // component.
        {
            let b = bsz.min(nq);
            let mut expect = vec![0.0; b];
            for j in 0..kb {
                packed::quad_form_multi_fast(
                    &arenas.mats[j * tri..(j + 1) * tri],
                    d,
                    &es[..b * d],
                    b,
                    &mut wide[..b * d],
                    &mut expect[..b],
                );
                packed::quad_form_multi_f32(
                    &mats32[j * tri..(j + 1) * tri],
                    d,
                    &es32[..b * d],
                    b,
                    &mut wide32[..b * d],
                    &mut out[..b],
                );
                for (q, (&a, &f)) in out[..b].iter().zip(expect.iter()).enumerate() {
                    assert!(
                        (a - f).abs() <= 1e-3 * (1.0 + a.abs().max(f.abs())),
                        "D={d}: f32 replica diverged past 1e-3 at component {j} \
                         query {q} ({a} vs {f})"
                    );
                }
            }
        }
        if !quick && d >= 1024 {
            min_rep_speedup_large_d = min_rep_speedup_large_d.min(speedup);
        }

        t4.row(&[
            d.to_string(),
            kb.to_string(),
            bsz.to_string(),
            format!("{f64_rate:.3e}"),
            format!("{f32_rate:.3e}"),
            format!("{speedup:5.2}×"),
        ]);
        rep_rows.push(Json::obj(vec![
            ("d", Json::from(d)),
            ("k", Json::from(kb)),
            ("b", Json::from(bsz)),
            ("f64_blocked_q_per_s", f64_rate.into()),
            ("f32_blocked_q_per_s", f32_rate.into()),
            ("f32_speedup", speedup.into()),
        ]));
    }
    if !quick {
        assert!(
            min_rep_speedup_large_d >= 1.5,
            "f32 replica kernels at B=32 must be ≥1.5× the f64 blocked rate at D ≥ 1024, \
             got {min_rep_speedup_large_d:.2}×"
        );
    }

    // ---- ComponentStore reservation record --------------------------
    let (reserved_moved, reserved_cap) = reservation_probe(true);
    let (unreserved_moved, unreserved_cap) = reservation_probe(false);
    assert!(
        !reserved_moved,
        "reserved arenas must keep stable base addresses across creates"
    );
    println!(
        "\nreservation: reserved base moved = {reserved_moved} (cap {reserved_cap} rows), \
         unreserved base moved = {unreserved_moved} (cap {unreserved_cap} rows)"
    );

    let payload = Json::obj(vec![
        ("bench", "layout_bandwidth".into()),
        ("quick", quick.into()),
        ("rows", Json::Arr(rows)),
        ("strict_vs_fast", Json::Arr(mode_rows)),
        ("blocked_multi_query", Json::Arr(blk_rows)),
        ("simd_tier", tier.as_str().into()),
        ("f32_replica", Json::Arr(rep_rows)),
        (
            "reservation",
            Json::obj(vec![
                ("reserved_base_moved", reserved_moved.into()),
                ("reserved_capacity_rows", reserved_cap.into()),
                ("unreserved_base_moved", unreserved_moved.into()),
                ("unreserved_capacity_rows", unreserved_cap.into()),
            ]),
        ),
    ]);
    match write_bench_json("layout_bandwidth", &payload) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
    println!(
        "layout_bandwidth OK — packed ≡ dense bitwise; fast kernels within tolerance of \
         strict; blocked multi-query kernels ≡ per-point bitwise"
    );
}
