//! Reproduces paper **Table 2 (training time)** and **Table 3 (testing
//! time)**: original IGMN vs Fast IGMN on all Table-1 datasets, δ = 1,
//! β = 0 (single component — isolates the dimensionality cost), 2-fold
//! cross-validation, paired t-test marks at p = 0.05.
//!
//! The CIFAR-10 rows would take the original IGMN hours (the paper
//! measured 20 768 s on its machine); per DESIGN.md those rows run the
//! original on a small calibrated sample and extrapolate linearly in N
//! (at K = 1 the per-point cost is N-independent), clearly marked `~`.
//! Set FIGMN_BENCH_FULL=1 to run everything exactly.
//!
//! Run: `cargo bench --bench table2_table3`

use figmn::bench_support::gmm_eval::{
    extrapolate_igmn_test, extrapolate_igmn_train, run_gmm_cv, Variant,
};
use figmn::bench_support::{fmt_cell, significance_mark, TablePrinter};
use figmn::data::synth::{self, TABLE1};
use figmn::gmm::GmmConfig;
use figmn::stats::mean;

fn main() {
    let full = std::env::var("FIGMN_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let seed = 42;
    let cfg = GmmConfig::new(1).with_delta(1.0).with_beta(0.0).without_pruning();

    struct Row {
        name: &'static str,
        igmn_train: Vec<f64>,
        figmn_train: Vec<f64>,
        igmn_test: Vec<f64>,
        figmn_test: Vec<f64>,
        extrapolated: bool,
    }

    let mut rows = Vec::new();
    for spec in TABLE1.iter().filter(|s| s.name != "CIFAR-10b") {
        let data = synth::generate(spec, seed);
        eprintln!("… {} (N={}, D={})", spec.name, spec.instances, spec.attributes);

        // Fast IGMN: always run exactly.
        let fast = run_gmm_cv(&data, &cfg, Variant::Fast, seed);
        let figmn_train: Vec<f64> = fast.iter().map(|f| f.timings.train_seconds).collect();
        let figmn_test: Vec<f64> = fast.iter().map(|f| f.timings.test_seconds).collect();

        // Original IGMN: exact unless the row is CIFAR-scale.
        let too_big = spec.attributes > 1000 && !full;
        let (igmn_train, igmn_test, extrapolated) = if too_big {
            // Calibrate on a handful of points; scale to the fold size.
            let fold_n = spec.instances / 2;
            let tr = extrapolate_igmn_train(&data, &cfg, 4, fold_n);
            let te = extrapolate_igmn_test(&data, &cfg, 4, 2, fold_n);
            (vec![tr, tr], vec![te, te], true)
        } else {
            let orig = run_gmm_cv(&data, &cfg, Variant::Original, seed);
            (
                orig.iter().map(|f| f.timings.train_seconds).collect(),
                orig.iter().map(|f| f.timings.test_seconds).collect(),
                false,
            )
        };
        rows.push(Row { name: spec.name, igmn_train, figmn_train, igmn_test, figmn_test, extrapolated });
    }

    println!("\nTable 2 — Training Time (seconds; ○/● = significant increase/decrease, p=0.05)");
    let t = TablePrinter::new(&["dataset", "IGMN", "Fast IGMN", "", "speedup"], &[16, 20, 20, 2, 8]);
    let mut avg_igmn = Vec::new();
    let mut avg_figmn = Vec::new();
    for r in &rows {
        let mark = if r.extrapolated { '~' } else { significance_mark(&r.igmn_train, &r.figmn_train, 0.05) };
        t.row(&[
            r.name.to_string(),
            fmt_cell(&r.igmn_train),
            fmt_cell(&r.figmn_train),
            mark.to_string(),
            format!("{:6.1}×", mean(&r.igmn_train) / mean(&r.figmn_train).max(1e-9)),
        ]);
        avg_igmn.push(mean(&r.igmn_train));
        avg_figmn.push(mean(&r.figmn_train));
    }
    t.row(&[
        "Average".to_string(),
        format!("{:9.3}", mean(&avg_igmn)),
        format!("{:9.3}", mean(&avg_figmn)),
        " ".to_string(),
        format!("{:6.1}×", mean(&avg_igmn) / mean(&avg_figmn).max(1e-9)),
    ]);

    println!("\nTable 3 — Testing Time (seconds)");
    let t = TablePrinter::new(&["dataset", "IGMN", "Fast IGMN", "", "speedup"], &[16, 20, 20, 2, 8]);
    let mut avg_igmn = Vec::new();
    let mut avg_figmn = Vec::new();
    for r in &rows {
        let mark = if r.extrapolated { '~' } else { significance_mark(&r.igmn_test, &r.figmn_test, 0.05) };
        t.row(&[
            r.name.to_string(),
            fmt_cell(&r.igmn_test),
            fmt_cell(&r.figmn_test),
            mark.to_string(),
            format!("{:6.1}×", mean(&r.igmn_test) / mean(&r.figmn_test).max(1e-9)),
        ]);
        avg_igmn.push(mean(&r.igmn_test));
        avg_figmn.push(mean(&r.figmn_test));
    }
    t.row(&[
        "Average".to_string(),
        format!("{:9.3}", mean(&avg_igmn)),
        format!("{:9.3}", mean(&avg_figmn)),
        " ".to_string(),
        format!("{:6.1}×", mean(&avg_igmn) / mean(&avg_figmn).max(1e-9)),
    ]);
    println!("\n(~ = original-IGMN cost extrapolated from a calibrated sample; FIGMN_BENCH_FULL=1 to run exactly)");
}
