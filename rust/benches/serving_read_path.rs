//! Serving read-path experiment: snapshot-served read throughput vs
//! scorer-thread count while a learner streams concurrently through the
//! same model's write path.
//!
//! This is the empirical check for the coordinator's read–write split:
//! scoring is pure and served from immutable `ModelSnapshot`s, so read
//! throughput should scale with scorer threads even though the learn
//! path stays strictly sequential per shard. It also re-verifies the
//! split's correctness contract: snapshot scoring is bit-identical to a
//! serial model trained on the same prefix.
//!
//! Acceptance target (full mode, ≥ 4 cores): ≥ 2× read throughput at
//! D = 64 features, K ≥ 32 components with 4 scorers vs. 1 scorer,
//! under concurrent learn traffic.
//!
//! Run: `cargo bench --bench serving_read_path`
//! Quick (CI smoke): `FIGMN_BENCH_QUICK=1 cargo bench --bench serving_read_path`
//! Writes `BENCH_serving_read_path.json`.

use figmn::bench_support::{quick_mode, write_bench_json, TablePrinter};
use figmn::coordinator::{Metrics, ModelSpec, Registry, RoutingPolicy};
use figmn::gmm::supervised::supervised_figmn;
use figmn::gmm::{GmmConfig, IncrementalMixture};
use figmn::json::Json;
use figmn::rng::Pcg64;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

const D: usize = 64; // feature dims (joint = D + N_CLASSES)
const N_CLASSES: usize = 2;
const K_TARGET: usize = 40; // component cap; stream is built to reach ≥ 32
const SNAPSHOT_INTERVAL: usize = 32;

fn gmm_config() -> GmmConfig {
    GmmConfig::new(1)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_max_components(K_TARGET)
        .without_pruning()
}

/// Labeled stream around K_TARGET well-separated centers.
fn build_stream(n: usize, seed: u64) -> Vec<(Vec<f64>, usize)> {
    let mut rng = Pcg64::seed(seed);
    let centers: Vec<Vec<f64>> = (0..K_TARGET)
        .map(|_| (0..D).map(|_| rng.normal() * 40.0).collect())
        .collect();
    (0..n)
        .map(|i| {
            let c = i % K_TARGET;
            let x: Vec<f64> =
                centers[c].iter().map(|&v| v + rng.normal() * 0.5).collect();
            (x, c % N_CLASSES)
        })
        .collect()
}

/// The correctness gate: a snapshot exported after the prefix scores
/// bit-identically to the serial model that learned the same prefix.
fn verify_bit_identity(prefix: &[(Vec<f64>, usize)]) {
    let mut serial = supervised_figmn(gmm_config(), &[1.0; D], N_CLASSES);
    for (x, y) in prefix {
        serial.train_one(x, *y);
    }
    let snap = serial.snapshot().expect("trained model must snapshot");
    let mut rng = Pcg64::seed(7);
    for _ in 0..20 {
        let probe: Vec<f64> = (0..D).map(|_| rng.normal() * 30.0).collect();
        assert_eq!(
            snap.class_scores(&probe),
            serial.class_scores(&probe),
            "snapshot predict diverged from serial model"
        );
        let mut joint = probe.clone();
        joint.extend([1.0, 0.0]);
        assert!(
            snap.log_density(&joint) == serial.model().log_density(&joint),
            "snapshot log_density bits diverged from serial model"
        );
    }
    println!("  bit-identity OK (snapshot ≡ serial model on the same prefix)");
}

/// Measure read throughput with `scorers` scorer threads and `clients`
/// concurrent readers while a learner streams. Returns reads/sec.
fn measure(
    scorers: usize,
    clients: usize,
    reads_per_client: usize,
    warmup: &[(Vec<f64>, usize)],
    learn_stream: &[(Vec<f64>, usize)],
) -> f64 {
    let registry = Arc::new(Registry::new(Arc::new(Metrics::new())).with_scorers(scorers));
    registry
        .create(
            ModelSpec::new("serve", D, N_CLASSES)
                .with_gmm(gmm_config())
                .with_stds(vec![1.0; D])
                .with_shards(1, RoutingPolicy::RoundRobin)
                .with_snapshot_interval(SNAPSHOT_INTERVAL),
        )
        .unwrap();
    let router = registry.router("serve").unwrap();
    for (x, y) in warmup {
        router.learn(x.clone(), *y).unwrap();
    }
    // Drain the queue so the model holds the full warmup, then wait for
    // the snapshot to cover it (interval or idle republish) — bounded,
    // so a publishing regression fails the bench instead of hanging CI.
    registry.stats("serve").unwrap();
    let snap = router.shards()[0]
        .wait_snapshot_points(warmup.len() as u64, 5000)
        .expect("snapshot never caught up to the warmup stream");
    assert!(snap.num_components() >= 32, "stream must grow K ≥ 32");

    // Learner: keeps write traffic flowing for the whole measurement.
    let stop = Arc::new(AtomicBool::new(false));
    let learner = {
        let router = registry.router("serve").unwrap();
        let stop = stop.clone();
        let stream = learn_stream.to_vec();
        std::thread::spawn(move || {
            let mut i = 0usize;
            let mut learned = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let (x, y) = &stream[i % stream.len()];
                if router.learn(x.clone(), *y).is_err() {
                    break;
                }
                learned += 1;
                i += 1;
            }
            learned
        })
    };

    // Readers: each issues snapshot-served predicts and scores.
    let total_reads = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let router = registry.router("serve").unwrap();
        let total = total_reads.clone();
        let probes: Vec<Vec<f64>> = {
            let mut rng = Pcg64::seed(100 + c as u64);
            (0..16).map(|_| (0..D).map(|_| rng.normal() * 30.0).collect()).collect()
        };
        handles.push(std::thread::spawn(move || {
            for r in 0..reads_per_client {
                let p = &probes[r % probes.len()];
                router.predict_read(p).expect("read path must serve");
                total.fetch_add(1, Ordering::Relaxed);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    let learned = learner.join().unwrap();
    let reads = total_reads.load(Ordering::Relaxed);
    assert!(learned > 0, "learner must actually stream during the measurement");
    reads as f64 / secs
}

fn main() {
    let quick = quick_mode();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let scorer_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let clients = 8;
    let warmup_n = if quick { 600 } else { 2000 };
    let reads_per_client = if quick { 100 } else { 1500 };

    println!(
        "serving_read_path — snapshot read throughput vs scorers \
         (D={D}+{N_CLASSES}, K≥32, clients={clients}, cores={cores}{})",
        if quick { ", quick mode" } else { "" }
    );

    let warmup = build_stream(warmup_n, 42);
    let learn_stream = build_stream(2000, 43);
    verify_bit_identity(&warmup);

    let table = TablePrinter::new(&["scorers", "reads/s", "speedup"], &[8, 12, 10]);
    let mut rows: Vec<Json> = Vec::new();
    let mut base_rate = 0.0;
    let mut speedup_1_to_4 = 0.0;
    for &s in scorer_counts {
        let rate = measure(s, clients, reads_per_client, &warmup, &learn_stream);
        if s == 1 {
            base_rate = rate;
        }
        let speedup = rate / base_rate;
        if s == 4 {
            speedup_1_to_4 = speedup;
        }
        table.row(&[s.to_string(), format!("{rate:10.0}"), format!("{speedup:7.2}×")]);
        rows.push(Json::obj(vec![
            ("scorers", s.into()),
            ("clients", clients.into()),
            ("reads_per_sec", rate.into()),
            ("speedup_vs_one_scorer", speedup.into()),
        ]));
    }

    let payload = Json::obj(vec![
        ("bench", "serving_read_path".into()),
        ("dim_features", D.into()),
        ("n_classes", N_CLASSES.into()),
        ("k_target", K_TARGET.into()),
        ("snapshot_interval", SNAPSHOT_INTERVAL.into()),
        ("quick", quick.into()),
        ("cores", cores.into()),
        ("bit_identical", true.into()),
        ("speedup_1_to_4_scorers", speedup_1_to_4.into()),
        ("rows", Json::Arr(rows)),
    ]);
    match write_bench_json("serving_read_path", &payload) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }

    if !quick && cores >= 4 {
        assert!(
            speedup_1_to_4 >= 2.0,
            "4-scorer read speedup is {speedup_1_to_4:.2}× (< 2×) at D={D}, K≥32"
        );
        println!("serving_read_path OK — {speedup_1_to_4:.2}× read throughput 1→4 scorers");
    } else {
        println!(
            "serving_read_path done (speedup {speedup_1_to_4:.2}×; \
             assertion skipped: quick={quick}, cores={cores})"
        );
    }
}
