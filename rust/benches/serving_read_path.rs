//! Serving read-path experiment: snapshot-served read throughput vs
//! scorer-thread count while a learner streams concurrently through the
//! same model's write path.
//!
//! This is the empirical check for the coordinator's read–write split:
//! scoring is pure and served from immutable `ModelSnapshot`s, so read
//! throughput should scale with scorer threads even though the learn
//! path stays strictly sequential per shard. It also re-verifies the
//! split's correctness contract: snapshot scoring is bit-identical to a
//! serial model trained on the same prefix.
//!
//! Acceptance targets (full mode):
//!
//! - ≥ 2× read throughput at D = 64 features, K ≥ 32 components with 4
//!   scorers vs. 1 scorer, under concurrent learn traffic (≥ 4 cores).
//! - **Blocked-batch series**: the query-blocked `score_batch` at
//!   B = 32 sustains ≥ 2× the per-point `log_density` throughput at
//!   D ≥ 256, K ≥ 32 — the single-thread bandwidth win of streaming
//!   each packed component row once per query block.
//! - **Replica series** (recorded, gated on tolerance only): the same
//!   state served with the f32 read replica off vs on — the off arm is
//!   the f64 blocked path, the on arm streams half the bytes; the hard
//!   ≥1.5× kernel floor at D ≥ 1024 lives in `layout_bandwidth`.
//!
//! Run: `cargo bench --bench serving_read_path`
//! Quick (CI smoke): `FIGMN_BENCH_QUICK=1 cargo bench --bench serving_read_path`
//! Writes `BENCH_serving_read_path.json`.

use figmn::bench_support::{grown_model, quick_mode, write_bench_json, TablePrinter};
use figmn::coordinator::{Metrics, ModelSpec, Registry, RoutingPolicy};
use figmn::gmm::supervised::supervised_figmn;
use figmn::gmm::{GmmConfig, IncrementalMixture, KernelMode, ModelSnapshot, ReplicaMode};
use figmn::json::Json;
use figmn::rng::Pcg64;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

const D: usize = 64; // feature dims (joint = D + N_CLASSES)
const N_CLASSES: usize = 2;
const K_TARGET: usize = 40; // component cap; stream is built to reach ≥ 32
const SNAPSHOT_INTERVAL: usize = 32;

fn gmm_config() -> GmmConfig {
    GmmConfig::new(1)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_max_components(K_TARGET)
        .without_pruning()
}

/// Labeled stream around K_TARGET well-separated centers.
fn build_stream(n: usize, seed: u64) -> Vec<(Vec<f64>, usize)> {
    let mut rng = Pcg64::seed(seed);
    let centers: Vec<Vec<f64>> = (0..K_TARGET)
        .map(|_| (0..D).map(|_| rng.normal() * 40.0).collect())
        .collect();
    (0..n)
        .map(|i| {
            let c = i % K_TARGET;
            let x: Vec<f64> =
                centers[c].iter().map(|&v| v + rng.normal() * 0.5).collect();
            (x, c % N_CLASSES)
        })
        .collect()
}

/// The correctness gate: a snapshot exported after the prefix scores
/// bit-identically to the serial model that learned the same prefix.
fn verify_bit_identity(prefix: &[(Vec<f64>, usize)]) {
    let mut serial = supervised_figmn(gmm_config(), &[1.0; D], N_CLASSES);
    for (x, y) in prefix {
        serial.train_one(x, *y);
    }
    let snap = serial.snapshot().expect("trained model must snapshot");
    let mut rng = Pcg64::seed(7);
    for _ in 0..20 {
        let probe: Vec<f64> = (0..D).map(|_| rng.normal() * 30.0).collect();
        assert_eq!(
            snap.class_scores(&probe),
            serial.class_scores(&probe),
            "snapshot predict diverged from serial model"
        );
        let mut joint = probe.clone();
        joint.extend([1.0, 0.0]);
        assert!(
            snap.log_density(&joint) == serial.model().log_density(&joint),
            "snapshot log_density bits diverged from serial model"
        );
    }
    println!("  bit-identity OK (snapshot ≡ serial model on the same prefix)");
}

/// Measure read throughput with `scorers` scorer threads and `clients`
/// concurrent readers while a learner streams. Returns reads/sec.
fn measure(
    scorers: usize,
    clients: usize,
    reads_per_client: usize,
    warmup: &[(Vec<f64>, usize)],
    learn_stream: &[(Vec<f64>, usize)],
) -> f64 {
    let registry = Arc::new(Registry::new(Arc::new(Metrics::new())).with_scorers(scorers));
    registry
        .create(
            ModelSpec::new("serve", D, N_CLASSES)
                .with_gmm(gmm_config())
                .with_stds(vec![1.0; D])
                .with_shards(1, RoutingPolicy::RoundRobin)
                .with_snapshot_interval(SNAPSHOT_INTERVAL),
        )
        .unwrap();
    let router = registry.router("serve").unwrap();
    for (x, y) in warmup {
        router.learn(x.clone(), *y).unwrap();
    }
    // Drain the queue so the model holds the full warmup, then wait for
    // the snapshot to cover it (interval or idle republish) — bounded,
    // so a publishing regression fails the bench instead of hanging CI.
    registry.stats("serve").unwrap();
    let snap = router.shards()[0]
        .wait_snapshot_points(warmup.len() as u64, 5000)
        .expect("snapshot never caught up to the warmup stream");
    assert!(snap.num_components() >= 32, "stream must grow K ≥ 32");

    // Learner: keeps write traffic flowing for the whole measurement.
    let stop = Arc::new(AtomicBool::new(false));
    let learner = {
        let router = registry.router("serve").unwrap();
        let stop = stop.clone();
        let stream = learn_stream.to_vec();
        std::thread::spawn(move || {
            let mut i = 0usize;
            let mut learned = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let (x, y) = &stream[i % stream.len()];
                if router.learn(x.clone(), *y).is_err() {
                    break;
                }
                learned += 1;
                i += 1;
            }
            learned
        })
    };

    // Readers: each issues snapshot-served predicts and scores.
    let total_reads = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let router = registry.router("serve").unwrap();
        let total = total_reads.clone();
        let probes: Vec<Vec<f64>> = {
            let mut rng = Pcg64::seed(100 + c as u64);
            (0..16).map(|_| (0..D).map(|_| rng.normal() * 30.0).collect()).collect()
        };
        handles.push(std::thread::spawn(move || {
            for r in 0..reads_per_client {
                let p = &probes[r % probes.len()];
                router.predict_read(p).expect("read path must serve");
                total.fetch_add(1, Ordering::Relaxed);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    let learned = learner.join().unwrap();
    let reads = total_reads.load(Ordering::Relaxed);
    assert!(learned > 0, "learner must actually stream during the measurement");
    reads as f64 / secs
}

/// Snapshot with exactly `k` components at joint dimension `d` for the
/// blocked-batch series (the shared grow-exactly-K recipe in
/// `bench_support`, also used by `tests/blocked_scoring_equivalence.rs`).
fn block_series_snapshot(d: usize, k: usize) -> ModelSnapshot {
    grown_model(d, k, KernelMode::Strict, 19).snapshot()
}

/// Blocked-vs-per-point scoring series: the same snapshot and probes,
/// scored through the per-point `log_density` loop (each query streams
/// all K packed matrices) and through the component-outer `score_batch`
/// at block sizes B ∈ {1, 8, 32}. Returns the minimum B=32 speedup
/// observed at D ≥ 256 (∞ when no such dim ran).
fn run_block_series(quick: bool, rows: &mut Vec<Json>) -> f64 {
    let dims: &[usize] = if quick { &[32] } else { &[64, 256, 1024] };
    let k = 32;
    let t = TablePrinter::new(
        &["D", "B", "per-pt q/s", "blocked q/s", "speedup"],
        &[6, 4, 13, 13, 9],
    );
    let mut min_speedup_large_d = f64::INFINITY;
    for &d in dims {
        let snap = block_series_snapshot(d, k);
        let n = if quick { 64 } else { (64_000_000 / (k * d * d)).clamp(32, 512) };
        let mut rng = Pcg64::seed(101);
        let probes: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.normal() * 500.0).collect()).collect();

        // Correctness gate first: blocking must not change any bits.
        let expect: Vec<f64> = probes.iter().map(|x| snap.log_density(x)).collect();
        assert_eq!(snap.score_batch(&probes), expect, "D={d}: blocked scoring diverged");

        let t0 = Instant::now();
        let mut sink = 0.0;
        for x in &probes {
            sink += snap.log_density(x);
        }
        let per_point = n as f64 / t0.elapsed().as_secs_f64();
        assert!(sink.is_finite());

        for &bsz in &[1usize, 8, 32] {
            let t0 = Instant::now();
            let mut sink = 0.0;
            for chunk in probes.chunks(bsz) {
                sink += snap.score_batch(chunk).iter().sum::<f64>();
            }
            let blocked = n as f64 / t0.elapsed().as_secs_f64();
            assert!(sink.is_finite());
            let speedup = blocked / per_point;
            if bsz == 32 && d >= 256 {
                min_speedup_large_d = min_speedup_large_d.min(speedup);
            }
            t.row(&[
                d.to_string(),
                bsz.to_string(),
                format!("{per_point:.3e}"),
                format!("{blocked:.3e}"),
                format!("{speedup:7.2}×"),
            ]);
            rows.push(Json::obj(vec![
                ("d", Json::from(d)),
                ("k", Json::from(k)),
                ("b", Json::from(bsz)),
                ("per_point_q_per_s", per_point.into()),
                ("blocked_q_per_s", blocked.into()),
                ("blocked_speedup", speedup.into()),
            ]));
        }
    }
    min_speedup_large_d
}

/// Replica-tier series: identical mixture state served through the
/// query-blocked `score_batch` with the f32 read replica off vs on.
/// The off arm is the f64 blocked path (the tier's baseline); the on
/// arm streams half the bytes per sweep. Tolerance gate: replica-served
/// densities within the contract's default 1e-3 relative of the f64
/// path. The hard ≥1.5× kernel floor at D ≥ 1024 lives in
/// `layout_bandwidth`; this series records the end-to-end snapshot
/// surface, replica bytes included.
fn run_replica_series(quick: bool, rows: &mut Vec<Json>) {
    let dims: &[usize] = if quick { &[32] } else { &[64, 256, 1024] };
    let k = 32;
    let bsz = 32;
    let t = TablePrinter::new(
        &["D", "off q/s", "replica q/s", "speedup", "replica MB"],
        &[6, 13, 13, 9, 11],
    );
    for &d in dims {
        let m = grown_model(d, k, KernelMode::Fast, 19);
        let off = m.snapshot();
        let rep = m.with_replica_mode(ReplicaMode::f32_default()).snapshot();
        assert!(!off.has_replica() && rep.has_replica());
        let n = if quick { 64 } else { (64_000_000 / (k * d * d)).clamp(32, 512) };
        let mut rng = Pcg64::seed(103);
        let probes: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.normal() * 500.0).collect()).collect();

        // Tolerance gate: the replica serves within the default
        // contract of the f64 path on every probe.
        let expect = off.score_batch(&probes);
        for (i, (a, f)) in rep.score_batch(&probes).iter().zip(expect.iter()).enumerate() {
            assert!(
                (a - f).abs() <= 1e-3 * (1.0 + a.abs().max(f.abs())),
                "D={d}: replica diverged past 1e-3 at probe {i} ({a} vs {f})"
            );
        }

        let t0 = Instant::now();
        let mut sink = 0.0;
        for chunk in probes.chunks(bsz) {
            sink += off.score_batch(chunk).iter().sum::<f64>();
        }
        let off_rate = n as f64 / t0.elapsed().as_secs_f64();
        assert!(sink.is_finite());

        let t0 = Instant::now();
        let mut sink = 0.0;
        for chunk in probes.chunks(bsz) {
            sink += rep.score_batch(chunk).iter().sum::<f64>();
        }
        let rep_rate = n as f64 / t0.elapsed().as_secs_f64();
        assert!(sink.is_finite());
        let speedup = rep_rate / off_rate;

        t.row(&[
            d.to_string(),
            format!("{off_rate:.3e}"),
            format!("{rep_rate:.3e}"),
            format!("{speedup:7.2}×"),
            format!("{:9.2}", rep.replica_bytes() as f64 / (1 << 20) as f64),
        ]);
        rows.push(Json::obj(vec![
            ("d", Json::from(d)),
            ("k", Json::from(k)),
            ("b", Json::from(bsz)),
            ("replica_off_q_per_s", off_rate.into()),
            ("replica_on_q_per_s", rep_rate.into()),
            ("replica_speedup", speedup.into()),
            ("model_bytes", off.model_bytes().into()),
            ("replica_bytes", rep.replica_bytes().into()),
        ]));
    }
}

fn main() {
    let quick = quick_mode();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let scorer_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let clients = 8;
    let warmup_n = if quick { 600 } else { 2000 };
    let reads_per_client = if quick { 100 } else { 1500 };

    println!(
        "serving_read_path — snapshot read throughput vs scorers \
         (D={D}+{N_CLASSES}, K≥32, clients={clients}, cores={cores}{})",
        if quick { ", quick mode" } else { "" }
    );

    let warmup = build_stream(warmup_n, 42);
    let learn_stream = build_stream(2000, 43);
    verify_bit_identity(&warmup);

    let table = TablePrinter::new(&["scorers", "reads/s", "speedup"], &[8, 12, 10]);
    let mut rows: Vec<Json> = Vec::new();
    let mut base_rate = 0.0;
    let mut speedup_1_to_4 = 0.0;
    for &s in scorer_counts {
        let rate = measure(s, clients, reads_per_client, &warmup, &learn_stream);
        if s == 1 {
            base_rate = rate;
        }
        let speedup = rate / base_rate;
        if s == 4 {
            speedup_1_to_4 = speedup;
        }
        table.row(&[s.to_string(), format!("{rate:10.0}"), format!("{speedup:7.2}×")]);
        rows.push(Json::obj(vec![
            ("scorers", s.into()),
            ("clients", clients.into()),
            ("reads_per_sec", rate.into()),
            ("speedup_vs_one_scorer", speedup.into()),
        ]));
    }

    println!(
        "\nblocked-batch series — per-point log_density vs query-blocked \
         score_batch (K=32, single thread{})",
        if quick { ", quick mode" } else { "" }
    );
    let mut block_rows: Vec<Json> = Vec::new();
    let min_block_speedup = run_block_series(quick, &mut block_rows);

    println!(
        "\nreplica series — f32 read replica off vs on through score_batch \
         (K=32, B=32, single thread{})",
        if quick { ", quick mode" } else { "" }
    );
    let mut replica_rows: Vec<Json> = Vec::new();
    run_replica_series(quick, &mut replica_rows);

    let payload = Json::obj(vec![
        ("bench", "serving_read_path".into()),
        ("dim_features", D.into()),
        ("n_classes", N_CLASSES.into()),
        ("k_target", K_TARGET.into()),
        ("snapshot_interval", SNAPSHOT_INTERVAL.into()),
        ("quick", quick.into()),
        ("cores", cores.into()),
        ("bit_identical", true.into()),
        ("speedup_1_to_4_scorers", speedup_1_to_4.into()),
        ("rows", Json::Arr(rows)),
        ("block_series", Json::Arr(block_rows)),
        ("replica_series", Json::Arr(replica_rows)),
    ]);
    match write_bench_json("serving_read_path", &payload) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }

    if !quick {
        // The ≥2× floor holds even on machines whose L3 swallows the
        // D=256 model (~8.4 MB): the strict per-point quadratic form is
        // one loop-carried FP chain (latency-bound at any cache level),
        // while the blocked kernel runs four independent per-query
        // chains per row — ILP the per-point path cannot reach — on top
        // of the bandwidth saving that dominates once the triangles
        // outgrow cache (D ≥ 1024).
        assert!(
            min_block_speedup >= 2.0,
            "blocked score_batch at B=32 is {min_block_speedup:.2}× (< 2×) the per-point \
             path at some D ≥ 256, K=32"
        );
        println!(
            "blocked-batch OK — ≥{min_block_speedup:.2}× over per-point at D≥256, K=32, B=32"
        );
    }
    if !quick && cores >= 4 {
        assert!(
            speedup_1_to_4 >= 2.0,
            "4-scorer read speedup is {speedup_1_to_4:.2}× (< 2×) at D={D}, K≥32"
        );
        println!("serving_read_path OK — {speedup_1_to_4:.2}× read throughput 1→4 scorers");
    } else {
        println!(
            "serving_read_path done (speedup {speedup_1_to_4:.2}×; \
             scorer assertion skipped: quick={quick}, cores={cores})"
        );
    }
}
