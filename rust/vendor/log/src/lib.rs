//! Offline stand-in for the `log` facade crate.
//!
//! The container's vendor set has no crates.io access, so this tiny
//! path-dependency provides the macro surface the codebase uses
//! (`log::warn!`, `log::debug!`, …). Messages go to stderr only when
//! `FIGMN_LOG=1` is set; otherwise logging is a no-op. Replace with the
//! real `log` crate via a registry dependency when one is available.

/// Emit a record (used by the macros; not part of the real `log` API).
pub fn __emit(level: &str, args: std::fmt::Arguments<'_>) {
    if std::env::var_os("FIGMN_LOG").is_some() {
        eprintln!("[{level}] {args}");
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__emit("ERROR", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__emit("WARN", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__emit("INFO", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__emit("DEBUG", format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__emit("TRACE", format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_expand_and_run() {
        crate::error!("e {}", 1);
        crate::warn!("w {}", 2);
        crate::info!("i {}", 3);
        crate::debug!("d {}", 4);
        crate::trace!("t {}", 5);
    }
}
