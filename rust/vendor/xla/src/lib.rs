//! Offline stub of the `xla` (xla_extension) PJRT binding.
//!
//! The real binding links against a prebuilt XLA shared library that is
//! not in this container. This stub keeps `figmn::runtime` compiling with
//! the exact same API surface; [`PjRtClient::cpu`] fails cleanly, so every
//! caller falls back to the native Rust path (the coordinator workers and
//! the CLI already handle that fallback — artifacts are optional).
//!
//! To use real XLA artifacts, point Cargo at the actual binding with a
//! `[patch]` section; no source changes are needed.

use std::fmt;

/// Error type mirroring `xla::Error` (message-only here).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!("{what}: xla runtime not available in this offline build"))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// A host-side literal (shape-erased; carries nothing in the stub).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// 1-D f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Scalar f32 literal.
    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer handle.
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; returns per-device, per-output
    /// buffers in the real binding.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub, so
/// no other method here is reachable in practice.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn constructors_are_callable() {
        let l = Literal::vec1(&[1.0, 2.0]);
        assert!(l.reshape(&[2, 1]).is_ok());
        let _ = Literal::scalar(0.5);
        assert!(Literal.to_vec::<f32>().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
