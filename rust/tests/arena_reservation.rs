//! Arena reservation + raw-pointer-staleness regression tests.
//!
//! The engine's sharded passes hold raw arena base pointers
//! (`StoreRawMut`) for the duration of a pass. PR 3 left a latent
//! hazard: if any `push` reallocated an arena while such a view was
//! live, the pointers would dangle. Two defenses landed together:
//!
//! - **Reservation**: `GmmConfig::max_components` pre-sizes all five
//!   `ComponentStore` arenas, so creates never reallocate (and never
//!   move the hot rows) mid-stream; unreserved stores grow all arenas
//!   together, geometrically.
//! - **Generation guard**: every push/truncate bumps a store
//!   generation; `StoreRawMut::row_mut` debug-asserts the generation
//!   is unchanged (covered by unit tests in `gmm::store`).
//!
//! The tests here drive the public API: streams that interleave
//! creates with engine passes at thread counts {1, 2, 4} must stay
//! bit-identical to the serial path (in debug builds the generation
//! guard would fire if a pass ever held a view across a create), and
//! reserved models must keep stable arena bases for their whole life.

use figmn::engine::EngineConfig;
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture, KernelMode};
use figmn::rng::Pcg64;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// A stream engineered to keep creating components between (and only
/// between) engine passes: clustered points that update, interleaved
/// with novel far-away points that create, all the way up to the cap.
fn creating_stream(d: usize, n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    let mut centers: Vec<Vec<f64>> = vec![(0..d).map(|_| rng.normal()).collect()];
    (0..n)
        .map(|i| {
            if i % 3 == 0 {
                // Novel: a fresh far-away center → create.
                let c: Vec<f64> =
                    (0..d).map(|_| rng.normal() * 5.0 + (centers.len() * 50) as f64).collect();
                centers.push(c.clone());
                c
            } else {
                // Revisit a known center → update pass over all rows.
                let c = &centers[i % centers.len()];
                c.iter().map(|&m| m + rng.normal() * 0.3).collect()
            }
        })
        .collect()
}

/// Creates interleaved with sharded engine passes, at every thread
/// count, with and without reservation: trajectories stay bit-identical
/// to the serial path, and (in debug builds) the generation guard
/// proves no raw view ever spanned a create.
#[test]
fn creates_across_engine_passes_bit_identical() {
    let d = 16;
    let stream = creating_stream(d, 400, 29);
    for (reserve, mode) in
        [(true, KernelMode::Strict), (false, KernelMode::Strict), (true, KernelMode::Fast)]
    {
        let mut cfg = GmmConfig::new(d)
            .with_delta(1.0)
            .with_beta(0.05)
            .with_kernel_mode(mode)
            .without_pruning();
        if reserve {
            cfg = cfg.with_max_components(256);
        }
        let stds = vec![2.0; d];

        let mut serial = Figmn::new(cfg.clone(), &stds);
        for x in &stream {
            serial.learn(x);
        }
        assert!(
            serial.num_components() >= 32,
            "stream too tame: only {} components",
            serial.num_components()
        );

        for t in THREAD_COUNTS {
            let mut pooled = Figmn::new(cfg.clone(), &stds).with_engine(EngineConfig::new(t));
            for x in &stream {
                pooled.learn(x);
            }
            assert_eq!(
                serial.num_components(),
                pooled.num_components(),
                "reserve={reserve} T={t}: K diverged"
            );
            for j in 0..serial.num_components() {
                assert_eq!(
                    serial.component_mean(j),
                    pooled.component_mean(j),
                    "reserve={reserve} T={t}: mean[{j}]"
                );
                assert_eq!(
                    serial.store().mat(j),
                    pooled.store().mat(j),
                    "reserve={reserve} T={t}: mat[{j}]"
                );
                assert_eq!(
                    serial.component_stats(j),
                    pooled.component_stats(j),
                    "reserve={reserve} T={t}: sp/v[{j}]"
                );
            }
        }
    }
}

/// With `max_components` set, the arena bases never move: the address
/// of row 0 is stable from first create to cap, across engine passes.
#[test]
fn reserved_arenas_keep_stable_bases() {
    let d = 8;
    let cap = 96;
    let cfg = GmmConfig::new(d)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_max_components(cap)
        .without_pruning();
    let stds = vec![2.0; d];
    let mut m = Figmn::new(cfg, &stds).with_engine(EngineConfig::new(2));
    assert!(m.store().capacity_rows() >= cap);

    let stream = creating_stream(d, 600, 41);
    m.learn(&stream[0]);
    let mean_base = m.store().mean(0).as_ptr();
    let mat_base = m.store().mat(0).as_ptr();
    for x in &stream[1..] {
        m.learn(x);
    }
    assert_eq!(m.num_components(), cap, "stream must fill the cap");
    assert!(
        std::ptr::eq(mean_base, m.store().mean(0).as_ptr()),
        "means arena moved despite reservation"
    );
    assert!(
        std::ptr::eq(mat_base, m.store().mat(0).as_ptr()),
        "matrix arena moved despite reservation"
    );
}

/// Restored (checkpoint-loaded) models re-reserve their headroom.
#[test]
fn restored_models_reserve_remaining_headroom() {
    let d = 4;
    let cap = 32;
    let cfg = GmmConfig::new(d)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_max_components(cap)
        .without_pruning();
    let mut m = Figmn::new(cfg, &[2.0; 4]);
    for x in creating_stream(d, 30, 77) {
        m.learn(&x);
    }
    assert!(m.num_components() < cap);
    let restored =
        Figmn::from_json(&figmn::json::parse(&m.to_json().to_string_compact()).unwrap()).unwrap();
    assert!(
        restored.store().capacity_rows() >= cap,
        "restored model must reserve up to max_components ({} < {cap})",
        restored.store().capacity_rows()
    );
}
