//! Blocked batch scoring ≡ per-point scoring — the query-blocking
//! contract.
//!
//! Every batch scoring surface (`Figmn::{score_batch, predict_batch}`,
//! `ModelSnapshot::{score_batch, posteriors_batch, predict_batch,
//! class_scores_batch}`, `SupervisedGmm::class_scores_batch`) runs
//! component-outer over 32-query blocks, streaming each packed
//! component row once per block. Blocking reorders *which query*
//! consumes a matrix value next — never the operations within a query —
//! so batch results must equal mapping the per-point entry points:
//!
//! - **Strict mode: bit-identical**, by the multi-kernel contract
//!   (`linalg::packed`).
//! - **Fast mode: bit-identical too** (the fast multi kernels perform
//!   each query's fast per-point sequence), which is strictly stronger
//!   than the 1e-12 tolerance the mode guarantees against Strict.
//!
//! The matrix covers B ∈ {1, 3, 8, 33} (tile tails and the ragged
//! 32+1 block), K ∈ {1, 4, 64}, D ∈ {2, 16, 128}, engine thread counts
//! {1, 2, 4}, and snapshot ⇄ model agreement.

use figmn::bench_support::{grow_config, grow_stream, grown_model};
use figmn::engine::EngineConfig;
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture, KernelMode};
use figmn::rng::Pcg64;

const DIMS: [usize; 3] = [2, 16, 128];
const KS: [usize; 3] = [1, 4, 64];
const BS: [usize; 4] = [1, 3, 8, 33];

fn probes(d: usize, b: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    (0..b).map(|_| (0..d).map(|_| rng.normal() * 500.0).collect()).collect()
}

/// Known/target split for the conditional surfaces: all-but-last known,
/// last dim reconstructed.
fn split(d: usize) -> (Vec<usize>, Vec<usize>) {
    ((0..d - 1).collect(), vec![d - 1])
}

/// Assert every batch surface of `m` (and its snapshot) equals the
/// per-point mapping, bitwise, on `b` probes.
fn assert_blocked_equals_per_point(m: &Figmn, d: usize, b: usize, tag: &str) {
    let xs = probes(d, b, 0xB10C + b as u64);
    let snap = m.snapshot();

    let expect: Vec<f64> = xs.iter().map(|x| m.log_density(x)).collect();
    assert_eq!(m.score_batch(&xs), expect, "{tag}: model score_batch");
    assert_eq!(snap.score_batch(&xs), expect, "{tag}: snapshot score_batch");

    let expect_post: Vec<Vec<f64>> = xs.iter().map(|x| m.posteriors(x)).collect();
    assert_eq!(snap.posteriors_batch(&xs), expect_post, "{tag}: posteriors_batch");

    let (known, target) = split(d);
    let kvs: Vec<Vec<f64>> = xs.iter().map(|x| x[..d - 1].to_vec()).collect();
    let expect_pred: Vec<Vec<f64>> =
        kvs.iter().map(|kv| m.predict(kv, &known, &target)).collect();
    assert_eq!(
        m.predict_batch(&kvs, &known, &target),
        expect_pred,
        "{tag}: model predict_batch"
    );
    assert_eq!(
        snap.predict_batch(&kvs, &known, &target),
        expect_pred,
        "{tag}: snapshot predict_batch"
    );
}

/// Strict mode: blocked ≡ per-point, bitwise, across the full
/// B × K × D matrix (including the ragged 32+1 tail at B = 33).
#[test]
fn strict_blocked_bit_identical_to_per_point() {
    for &d in &DIMS {
        for &k in &KS {
            let m = grown_model(d, k, KernelMode::Strict, 7);
            for &b in &BS {
                assert_blocked_equals_per_point(&m, d, b, &format!("strict d={d} k={k} b={b}"));
            }
        }
    }
}

/// Fast mode: the fast multi kernels run each query's fast per-point
/// sequence, so blocked ≡ per-point bitwise here too — which subsumes
/// the mode's 1e-12 tolerance contract.
#[test]
fn fast_blocked_bit_identical_to_fast_per_point() {
    for &d in &DIMS {
        for &k in &KS {
            let m = grown_model(d, k, KernelMode::Fast, 7);
            for &b in &BS {
                assert_blocked_equals_per_point(&m, d, b, &format!("fast d={d} k={k} b={b}"));
            }
        }
    }
}

/// Fast-mode blocked scoring tracks a strict twin (same stream, same
/// decisions) to relative 1e-12 — the cross-mode tolerance contract,
/// now holding through the blocked path as well.
#[test]
fn fast_blocked_tracks_strict_to_1e12() {
    let (d, k, b) = (16, 4, 33);
    let mut strict = Figmn::new(grow_config(d, k, KernelMode::Strict), &vec![1.0; d]);
    let mut fast = Figmn::new(grow_config(d, k, KernelMode::Fast), &vec![1.0; d]);
    for x in grow_stream(d, k, 7) {
        assert_eq!(strict.learn(&x), fast.learn(&x), "create/update decisions diverged");
    }
    assert_eq!(strict.num_components(), fast.num_components());
    let xs = probes(d, b, 0xFA57);
    let a = strict.score_batch(&xs);
    let c = fast.score_batch(&xs);
    for (i, (x, y)) in a.iter().zip(c.iter()).enumerate() {
        assert!(
            (x - y).abs() <= 1e-12 * (1.0 + x.abs().max(y.abs())),
            "query {i}: fast blocked diverged past 1e-12 ({x} vs {y})"
        );
    }
}

/// Engine thread counts {1, 2, 4} reproduce the serial blocked path bit
/// for bit, in both modes — the K×B tiling shards only the K axis, and
/// the per-query merges stay schedule-independent.
#[test]
fn blocked_batches_bit_identical_across_thread_counts() {
    for (d, k) in [(16usize, 64usize), (128, 4)] {
        for mode in [KernelMode::Strict, KernelMode::Fast] {
            let serial = grown_model(d, k, mode, 11);
            let xs = probes(d, 33, 0x7EAD);
            let (known, target) = split(d);
            let kvs: Vec<Vec<f64>> = xs.iter().map(|x| x[..d - 1].to_vec()).collect();
            let expect_scores = serial.score_batch(&xs);
            let expect_preds = serial.predict_batch(&kvs, &known, &target);
            for t in [1usize, 2, 4] {
                let mut pooled = Figmn::new(grow_config(d, k, mode), &vec![1.0; d])
                    .with_engine(EngineConfig::new(t));
                pooled.learn_batch(&grow_stream(d, k, 11));
                assert_eq!(pooled.num_components(), k, "d={d} k={k} T={t}: K");
                assert_eq!(
                    pooled.score_batch(&xs),
                    expect_scores,
                    "d={d} k={k} T={t} {mode}: score_batch"
                );
                assert_eq!(
                    pooled.predict_batch(&kvs, &known, &target),
                    expect_preds,
                    "d={d} k={k} T={t} {mode}: predict_batch"
                );
            }
        }
    }
}

/// Block boundaries are invisible: scoring a 33-query batch in one call
/// equals scoring any split of it (32+1, 16+17, 1-at-a-time), bitwise.
#[test]
fn batch_results_are_partition_invariant() {
    let (d, k) = (16, 4);
    for mode in [KernelMode::Strict, KernelMode::Fast] {
        let m = grown_model(d, k, mode, 13);
        let xs = probes(d, 33, 0x5417);
        let whole = m.score_batch(&xs);
        for cut in [1usize, 16, 32] {
            let mut parts = m.score_batch(&xs[..cut]);
            parts.extend(m.score_batch(&xs[cut..]));
            assert_eq!(whole, parts, "{mode}: split at {cut}");
        }
        let singles: Vec<f64> =
            xs.iter().map(|x| m.score_batch(std::slice::from_ref(x))[0]).collect();
        assert_eq!(whole, singles, "{mode}: one-at-a-time");
    }
}

/// Supervised batch classification rides the blocked conditional path
/// and stays bit-identical to the per-point wrapper — model and
/// snapshot agree.
#[test]
fn supervised_blocked_classification_matches_per_point() {
    use figmn::gmm::supervised::supervised_figmn;
    let cfg = GmmConfig::new(8).with_delta(0.5).with_beta(0.05).without_pruning();
    let mut clf = supervised_figmn(cfg, &[3.0; 8], 3);
    let mut rng = Pcg64::seed(21);
    for i in 0..240 {
        let c = i % 3;
        let x: Vec<f64> = (0..8).map(|f| (c * 7 + f) as f64 + rng.normal() * 0.5).collect();
        clf.train_one(&x, c);
    }
    let snap = clf.snapshot().expect("trained model must snapshot");
    // 33 probes: ragged tail over the 32-query block.
    let probes: Vec<Vec<f64>> = (0..33)
        .map(|i| {
            let c = i % 3;
            (0..8).map(|f| (c * 7 + f) as f64 + rng.normal() * 0.5).collect()
        })
        .collect();
    let expect: Vec<Vec<f64>> = probes.iter().map(|x| clf.class_scores(x)).collect();
    assert_eq!(clf.class_scores_batch(&probes), expect, "wrapper class_scores_batch");
    assert_eq!(snap.class_scores_batch(&probes), expect, "snapshot class_scores_batch");
    assert_eq!(
        clf.predict_class_batch(&probes),
        probes.iter().map(|x| clf.predict_class(x)).collect::<Vec<_>>(),
        "predict_class_batch"
    );
}
