//! f32 read-replica and explicit-SIMD tier equivalence — the replica
//! tier's tolerance contract (`gmm::replica`).
//!
//! - **replica tolerance**: a snapshot published with
//!   `ReplicaMode::F32 { tol }` serves the density surfaces within
//!   `tol` relative of the f64 path, across dimensions spanning the
//!   cache-resident to bandwidth-bound regimes and both kernel modes;
//! - **off = byte-identical**: with `ReplicaMode::Off` (the default)
//!   every surface reproduces the f64 read path bit for bit — the
//!   pre-replica contract is untouched;
//! - **tier equivalence**: the explicit-SIMD f64 kernels track the
//!   `Fast` kernels within relative 1e-12 at every tier, and forcing
//!   `Scalar` (or any tier above the detected one) degrades to the
//!   portable kernel — never UB, never a panic.

use figmn::gmm::{
    Figmn, GmmConfig, IncrementalMixture, KernelMode, ReplicaMode, DEFAULT_F32_TOL,
};
use figmn::linalg::packed::{
    self, quad_form_multi_f32, quad_form_multi_f32_tier, quad_form_multi_fast,
    quad_form_multi_simd, quad_form_multi_simd_tier,
};
use figmn::linalg::{simd_tier, SimdTier};
use figmn::rng::Pcg64;
use figmn::testutil::random_spd;

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// Train a small well-separated mixture at dimension `d` and return it
/// with a probe set drawn from the same stream.
fn trained(d: usize, mode: KernelMode, replica: ReplicaMode) -> (Figmn, Vec<Vec<f64>>) {
    let cfg = GmmConfig::new(d)
        .with_delta(1.0)
        .with_beta(0.05)
        .without_pruning()
        .with_kernel_mode(mode)
        .with_replica_mode(replica);
    let mut m = Figmn::new(cfg, &vec![1.0; d]);
    let mut rng = Pcg64::seed(d as u64 + 17);
    // Few points at large D: learning is O(K·D²)/point and the replica
    // contract doesn't care how converged the mixture is.
    let points = if d >= 256 { 10 } else { 80 };
    let mut stream = Vec::new();
    for i in 0..points {
        let c = (i % 2) as f64 * 8.0;
        let x: Vec<f64> = (0..d).map(|_| c + rng.normal() * 0.5).collect();
        m.learn(&x);
        stream.push(x);
    }
    (m, stream)
}

/// D ∈ {2, 64, 256, 1024} × {Strict, Fast}: replica-served densities
/// and posteriors track the f64 path within the configured tolerance,
/// and the replica's blocked batch surfaces stay bit-identical to its
/// own per-point path (block size never changes a query's FP sequence).
#[test]
fn replica_tracks_f64_within_tol_across_dims_and_modes() {
    for d in [2usize, 64, 256, 1024] {
        for mode in [KernelMode::Strict, KernelMode::Fast] {
            let (m, stream) = trained(d, mode, ReplicaMode::f32_default());
            let snap = m.snapshot();
            assert!(snap.has_replica(), "D={d} {mode}: replica must publish");
            assert!(snap.replica_bytes() > 0, "D={d} {mode}: replica bytes");
            let n_probes = if d >= 256 { 4 } else { 16 };
            let probes: Vec<Vec<f64>> = stream.iter().rev().take(n_probes).cloned().collect();
            for (i, x) in probes.iter().enumerate() {
                let f64_ld = m.log_density(x);
                let rep_ld = snap.log_density(x);
                assert!(
                    rel_close(f64_ld, rep_ld, DEFAULT_F32_TOL),
                    "D={d} {mode}: log_density[{i}] {rep_ld} vs f64 {f64_ld}"
                );
                for (pa, pb) in snap.posteriors(x).iter().zip(m.posteriors(x).iter()) {
                    assert!(
                        (pa - pb).abs() <= DEFAULT_F32_TOL,
                        "D={d} {mode}: posterior[{i}] {pa} vs f64 {pb}"
                    );
                }
            }
            // Replica batch ≡ replica per-point, bitwise.
            let per_point: Vec<f64> = probes.iter().map(|x| snap.log_density(x)).collect();
            assert_eq!(snap.score_batch(&probes), per_point, "D={d} {mode}: batch");
            let per_post: Vec<Vec<f64>> = probes.iter().map(|x| snap.posteriors(x)).collect();
            assert_eq!(snap.posteriors_batch(&probes), per_post, "D={d} {mode}: posteriors");
        }
    }
}

/// `ReplicaMode::Off` keeps every surface byte-identical to the live
/// f64 model, and the conditional surfaces stay f64 (bit-identical to
/// the replica-off snapshot) even when a replica is published.
#[test]
fn off_is_byte_identical_and_conditionals_stay_f64() {
    let d = 6;
    let (m_off, stream) = trained(d, KernelMode::Fast, ReplicaMode::Off);
    let (m_rep, _) = trained(d, KernelMode::Fast, ReplicaMode::f32_default());
    let off = m_off.snapshot();
    let rep = m_rep.snapshot();
    assert!(!off.has_replica());
    assert_eq!(off.replica_bytes(), 0);
    // Replica mode is read-path-only: the two models trained on the
    // same stream hold identical arenas.
    assert_eq!(m_off.num_components(), m_rep.num_components());

    let probes: Vec<Vec<f64>> = stream.iter().rev().take(12).cloned().collect();
    let known_idx: Vec<usize> = (0..d - 1).collect();
    let target_idx = [d - 1];
    for x in &probes {
        // Off ⇒ bitwise the live f64 path.
        assert!(off.log_density(x) == m_off.log_density(x), "off diverged");
        assert_eq!(off.posteriors(x), m_off.posteriors(x));
        // predict stays Cholesky-bound f64 regardless of the replica.
        assert_eq!(
            rep.predict(&x[..d - 1], &known_idx, &target_idx),
            off.predict(&x[..d - 1], &known_idx, &target_idx),
            "predict must ignore the replica"
        );
    }
    assert_eq!(off.score_batch(&probes), m_off.score_batch(&probes));
}

/// The explicit-SIMD f64 ladder: forcing `Scalar` reproduces the `Fast`
/// kernel bit for bit, the auto tier and every forced tier (including
/// tiers above the detected one, which clamp) stay within relative
/// 1e-12, and the f32 kernel tracks f64 within its intrinsic tolerance
/// at every tier.
#[test]
fn simd_tiers_track_fast_and_clamp_safely() {
    let b = 7;
    for d in [5usize, 64, 257] {
        let mut rng = Pcg64::seed(d as u64);
        let ap = packed::pack_symmetric(&random_spd(d, &mut rng));
        let es: Vec<f64> = (0..b * d).map(|_| rng.normal()).collect();
        let mut ws = vec![0.0; b * d];

        let mut fast = vec![0.0; b];
        quad_form_multi_fast(&ap, d, &es, b, &mut ws, &mut fast);

        // Forced Scalar ≡ Fast, bitwise.
        let mut scalar = vec![0.0; b];
        quad_form_multi_simd_tier(&ap, d, &es, b, &mut ws, &mut scalar, SimdTier::Scalar);
        assert_eq!(scalar, fast, "D={d}: forced Scalar must run the Fast kernel");

        // Auto tier and every forced tier: within 1e-12, no UB/panic
        // even when forcing above the detected tier (it clamps).
        for tier in [SimdTier::Scalar, SimdTier::Fma, SimdTier::Avx512] {
            let mut out = vec![0.0; b];
            quad_form_multi_simd_tier(&ap, d, &es, b, &mut ws, &mut out, tier);
            for (i, (&a, &f)) in out.iter().zip(fast.iter()).enumerate() {
                assert!(rel_close(a, f, 1e-12), "D={d} {tier}: q[{i}] {a} vs fast {f}");
            }
        }
        let mut auto = vec![0.0; b];
        quad_form_multi_simd(&ap, d, &es, b, &mut ws, &mut auto);
        let mut detected = vec![0.0; b];
        quad_form_multi_simd_tier(&ap, d, &es, b, &mut ws, &mut detected, simd_tier());
        assert_eq!(auto, detected, "D={d}: auto must dispatch the detected tier");

        // f32 kernel: every tier tracks the f64 Fast result within the
        // f32 intrinsic tolerance, and the auto dispatch is
        // deterministic (two calls agree bitwise).
        let ap32: Vec<f32> = ap.iter().map(|&v| v as f32).collect();
        let es32: Vec<f32> = es.iter().map(|&v| v as f32).collect();
        let mut ws32 = vec![0.0f32; b * d];
        for tier in [SimdTier::Scalar, SimdTier::Fma, SimdTier::Avx512] {
            let mut out = vec![0.0; b];
            quad_form_multi_f32_tier(&ap32, d, &es32, b, &mut ws32, &mut out, tier);
            for (i, (&a, &f)) in out.iter().zip(fast.iter()).enumerate() {
                assert!(rel_close(a, f, 1e-3), "D={d} {tier}: f32 q[{i}] {a} vs f64 {f}");
            }
        }
        let mut f32_a = vec![0.0; b];
        let mut f32_b = vec![0.0; b];
        quad_form_multi_f32(&ap32, d, &es32, b, &mut ws32, &mut f32_a);
        quad_form_multi_f32(&ap32, d, &es32, b, &mut ws32, &mut f32_b);
        assert_eq!(f32_a, f32_b, "D={d}: f32 auto dispatch must be deterministic");
    }
}
