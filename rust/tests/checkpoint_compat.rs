//! Checkpoint backward compatibility: pre-refactor (v1) checkpoints —
//! the per-component format with a dense row-major `lambda` — must load
//! into the new packed `ComponentStore` and score **bit-identically**.
//!
//! Three angles:
//! - `v1_document_loads_and_scores_bit_identically` synthesizes a v1
//!   document with exactly the pre-refactor writer's fields (the dense
//!   matrix reconstructed from the packed arenas — identical values,
//!   since the update rules keep Λ exactly symmetric) and checks the
//!   loaded model against the live one, bit for bit, including
//!   continued learning.
//! - `static_v1_fixture_loads` pins the on-disk format itself with a
//!   committed fixture file, cross-checked against an identical model
//!   assembled through the independent `PackedState` wire-format path.
//! - The same contract for the covariance baseline: a committed v1
//!   `Igmn` fixture (dense per-component `cov`) loads and scores
//!   bit-identically to its v2 re-save, and v2 documents carrying the
//!   additive `kernel_mode` field degrade gracefully on readers that
//!   drop it.

use figmn::gmm::{CHECKPOINT_MIN_VERSION, Figmn, GmmConfig, Igmn, IncrementalMixture, KernelMode};
use figmn::json::{parse, Json};
use figmn::rng::Pcg64;
use figmn::runtime::PackedState;

fn trained_model() -> Figmn {
    let cfg = GmmConfig::new(3).with_delta(0.4).with_beta(0.1).with_pruning(5, 0.5);
    let mut m = Figmn::new(cfg, &[2.0, 2.0, 2.0]);
    let mut rng = Pcg64::seed(31);
    for _ in 0..250 {
        let c = if rng.uniform() < 0.5 { 0.0 } else { 8.0 };
        let x: Vec<f64> = (0..3).map(|_| c + rng.normal()).collect();
        m.learn(&x);
    }
    m
}

/// Re-emit a live model in the exact pre-refactor v1 checkpoint format:
/// version 1, per-component dense row-major `lambda`.
fn to_v1_doc(m: &Figmn) -> Json {
    let cfg = m.config();
    let comps: Vec<Json> = (0..m.num_components())
        .map(|j| {
            let lam = m.component_lambda(j); // dense expansion
            let (sp, v) = m.component_stats(j);
            Json::obj(vec![
                ("mean", Json::num_array(m.component_mean(j))),
                ("lambda", Json::num_array(lam.as_slice())),
                ("log_det", m.component_log_det(j).into()),
                ("sp", sp.into()),
                ("v", (v as usize).into()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("version", CHECKPOINT_MIN_VERSION.into()),
        ("crate_version", "0.1.0".into()),
        ("kind", "figmn".into()),
        ("dim", cfg.dim.into()),
        ("delta", cfg.delta.into()),
        ("beta", cfg.beta.into()),
        ("v_min", (cfg.v_min as usize).into()),
        ("sp_min", cfg.sp_min.into()),
        ("prune", cfg.prune.into()),
        ("max_components", cfg.max_components.into()),
        ("sigma_ini", Json::num_array(m.sigma_ini())),
        ("points", (m.points_seen() as usize).into()),
        ("components", Json::Arr(comps)),
    ])
}

#[test]
fn v1_document_loads_and_scores_bit_identically() {
    let mut live = trained_model();
    let text = to_v1_doc(&live).to_string_compact();
    assert!(text.contains("\"version\":1"), "doc must be v1: {}", &text[..60]);
    assert!(text.contains("\"lambda\":["), "doc must carry the dense matrix");
    let mut restored = Figmn::from_json(&parse(&text).unwrap()).unwrap();

    assert_eq!(restored.num_components(), live.num_components());
    assert_eq!(restored.points_seen(), live.points_seen());
    let mut rng = Pcg64::seed(77);
    for _ in 0..20 {
        let x: Vec<f64> = (0..3).map(|_| rng.normal() * 4.0).collect();
        assert!(
            live.log_density(&x).to_bits() == restored.log_density(&x).to_bits(),
            "v1-loaded log_density bits differ"
        );
        assert_eq!(live.posteriors(&x), restored.posteriors(&x));
        assert_eq!(
            live.predict(&x[..2], &[0, 1], &[2]),
            restored.predict(&x[..2], &[0, 1], &[2]),
            "v1-loaded predict bits differ"
        );
    }
    // The restored model keeps learning exactly like the live one —
    // same outcomes, same state (full trajectory equivalence).
    for _ in 0..40 {
        let x: Vec<f64> = (0..3).map(|_| rng.normal() * 4.0).collect();
        assert_eq!(live.learn(&x), restored.learn(&x));
    }
    assert_eq!(live.num_components(), restored.num_components());
    for j in 0..live.num_components() {
        assert_eq!(live.component_mean(j), restored.component_mean(j));
        assert_eq!(
            live.component_lambda(j).as_slice(),
            restored.component_lambda(j).as_slice()
        );
    }
    // And re-saving produces a current-format (v2, packed) checkpoint.
    let resaved = restored.to_json().to_string_compact();
    assert!(resaved.contains("\"version\":2"));
    assert!(resaved.contains("\"lambda_packed\":["));
}

/// The v1 loader must reject corruption anywhere in the dense matrix —
/// including the lower triangle, which the packed store no longer
/// keeps. Silently dropping it would load a checkpoint the pre-refactor
/// reader either rejected (non-finite) or scored differently
/// (asymmetric).
#[test]
fn v1_corrupt_lower_triangle_is_rejected() {
    let good = r#"{"version":1,"kind":"figmn","dim":2,"delta":0.5,"beta":0.1,
        "v_min":5,"sp_min":3,"prune":false,"max_components":0,
        "sigma_ini":[1,1],"points":1,"components":[
        {"mean":[0,0],"lambda":[1,0.25,0.25,1],"log_det":0,"sp":1,"v":1}]}"#;
    assert!(Figmn::from_json(&parse(good).unwrap()).is_ok());
    // Non-numeric payload in the lower-triangle slot.
    let bad = good.replace("[1,0.25,0.25,1]", "[1,0.25,null,1]");
    assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err(), "null lower triangle");
    // Non-finite value (1e999 parses to +inf) hiding in the lower
    // triangle the packed store would otherwise drop.
    let bad = good.replace("[1,0.25,0.25,1]", "[1,0.25,1e999,1]");
    assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err(), "non-finite lower triangle");
    // Asymmetric dense matrix: the two readers would disagree — reject.
    let bad = good.replace("[1,0.25,0.25,1]", "[1,0.25,0.75,1]");
    assert!(Figmn::from_json(&parse(&bad).unwrap()).is_err(), "asymmetric lambda");
}

fn trained_igmn() -> Igmn {
    let cfg = GmmConfig::new(3).with_delta(0.4).with_beta(0.1).with_pruning(5, 0.5);
    let mut m = Igmn::new(cfg, &[2.0, 2.0, 2.0]);
    let mut rng = Pcg64::seed(37);
    for _ in 0..150 {
        let c = if rng.uniform() < 0.5 { 0.0 } else { 8.0 };
        let x: Vec<f64> = (0..3).map(|_| c + rng.normal()).collect();
        m.learn(&x);
    }
    m
}

/// Re-emit a live Igmn in the v1 format: version 1, per-component
/// dense row-major `cov`.
fn to_v1_igmn_doc(m: &Igmn) -> Json {
    let cfg = m.config();
    let comps: Vec<Json> = (0..m.num_components())
        .map(|j| {
            let cov = m.component_cov(j); // dense expansion
            let (sp, v) = m.component_stats(j);
            Json::obj(vec![
                ("mean", Json::num_array(m.component_mean(j))),
                ("cov", Json::num_array(cov.as_slice())),
                ("sp", sp.into()),
                ("v", (v as usize).into()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("version", CHECKPOINT_MIN_VERSION.into()),
        ("crate_version", "0.1.0".into()),
        ("kind", "igmn".into()),
        ("dim", cfg.dim.into()),
        ("delta", cfg.delta.into()),
        ("beta", cfg.beta.into()),
        ("v_min", (cfg.v_min as usize).into()),
        ("sp_min", cfg.sp_min.into()),
        ("prune", cfg.prune.into()),
        ("max_components", cfg.max_components.into()),
        ("sigma_ini", Json::num_array(m.sigma_ini())),
        ("points", (m.points_seen() as usize).into()),
        ("components", Json::Arr(comps)),
    ])
}

#[test]
fn v1_igmn_document_loads_and_scores_bit_identically() {
    let mut live = trained_igmn();
    let text = to_v1_igmn_doc(&live).to_string_compact();
    assert!(text.contains("\"version\":1"));
    assert!(text.contains("\"cov\":["), "doc must carry the dense covariance");
    let mut restored = Igmn::from_json(&parse(&text).unwrap()).unwrap();

    assert_eq!(restored.num_components(), live.num_components());
    assert_eq!(restored.points_seen(), live.points_seen());
    let mut rng = Pcg64::seed(71);
    for _ in 0..20 {
        let x: Vec<f64> = (0..3).map(|_| rng.normal() * 4.0).collect();
        assert!(
            live.log_density(&x).to_bits() == restored.log_density(&x).to_bits(),
            "v1-loaded igmn log_density bits differ"
        );
        assert_eq!(live.posteriors(&x), restored.posteriors(&x));
    }
    // Continued learning stays identical too.
    for _ in 0..30 {
        let x: Vec<f64> = (0..3).map(|_| rng.normal() * 4.0).collect();
        assert_eq!(live.learn(&x), restored.learn(&x));
    }
    assert_eq!(live.num_components(), restored.num_components());
    for j in 0..live.num_components() {
        assert_eq!(live.component_mean(j), restored.component_mean(j));
        assert_eq!(
            live.component_cov(j).as_slice(),
            restored.component_cov(j).as_slice()
        );
    }
    // Re-saving produces a current-format (v2, packed) checkpoint.
    let resaved = restored.to_json().to_string_compact();
    assert!(resaved.contains("\"version\":2"));
    assert!(resaved.contains("\"cov_packed\":["));
}

#[test]
fn v1_igmn_corrupt_lower_triangle_is_rejected() {
    let good = r#"{"version":1,"kind":"igmn","dim":2,"delta":0.5,"beta":0.1,
        "v_min":5,"sp_min":3,"prune":false,"max_components":0,
        "sigma_ini":[1,1],"points":1,"components":[
        {"mean":[0,0],"cov":[1,0.25,0.25,1],"sp":1,"v":1}]}"#;
    assert!(Igmn::from_json(&parse(good).unwrap()).is_ok());
    let bad = good.replace("[1,0.25,0.25,1]", "[1,0.25,1e999,1]");
    assert!(Igmn::from_json(&parse(&bad).unwrap()).is_err(), "non-finite lower triangle");
    let bad = good.replace("[1,0.25,0.25,1]", "[1,0.25,0.75,1]");
    assert!(Igmn::from_json(&parse(&bad).unwrap()).is_err(), "asymmetric cov");
    // A v1 igmn doc is not loadable as figmn and vice versa.
    assert!(Figmn::from_json(&parse(good).unwrap()).is_err());
}

#[test]
fn static_v1_igmn_fixture_loads() {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/fixtures/checkpoint_v1_igmn.json"
    );
    let text = std::fs::read_to_string(path).expect("fixture must exist");
    let loaded = Igmn::from_json(&parse(&text).unwrap()).expect("v1 igmn fixture must load");
    assert_eq!(loaded.dim(), 2);
    assert_eq!(loaded.num_components(), 2);
    assert_eq!(loaded.points_seen(), 7);
    assert_eq!(loaded.component_mean(1), &[4.0, 4.0]);
    assert_eq!(loaded.component_stats(0), (1.5, 3));
    assert_eq!(loaded.component_cov(0).as_slice(), &[1.0, 0.0, 0.0, 1.0]);
    // v1 docs predate kernel_mode: Strict by construction.
    assert_eq!(loaded.config().kernel_mode, KernelMode::Strict);

    // The v2 re-save round-trips to the exact same scoring behaviour.
    let resaved = Igmn::from_json(&parse(&loaded.to_json().to_string_compact()).unwrap()).unwrap();
    for x in [[0.5, -0.25], [3.5, 4.25], [2.0, 2.0]] {
        assert!(
            loaded.log_density(&x).to_bits() == resaved.log_density(&x).to_bits(),
            "fixture scoring diverged through the v2 round trip at {x:?}"
        );
        assert_eq!(loaded.posteriors(&x), resaved.posteriors(&x));
    }
}

/// v2 documents now carry the additive `kernel_mode` field; readers
/// that drop it (the pre-dual-mode reader behaviour) still load the
/// checkpoint — for both kinds.
#[test]
fn v2_kernel_mode_field_degrades_gracefully() {
    let fig = trained_model();
    let text = fig.to_json().to_string_compact();
    assert!(text.contains("\"kernel_mode\":\"strict\""));
    let stripped = text.replace("\"kernel_mode\":\"strict\",", "");
    let loaded = Figmn::from_json(&parse(&stripped).unwrap()).unwrap();
    assert_eq!(loaded.num_components(), fig.num_components());
    let mut rng = Pcg64::seed(13);
    for _ in 0..10 {
        let x: Vec<f64> = (0..3).map(|_| rng.normal() * 4.0).collect();
        assert_eq!(fig.log_density(&x), loaded.log_density(&x));
    }

    let ig = trained_igmn();
    let text = ig.to_json().to_string_compact();
    let stripped = text.replace("\"kernel_mode\":\"strict\",", "");
    let loaded = Igmn::from_json(&parse(&stripped).unwrap()).unwrap();
    assert_eq!(loaded.num_components(), ig.num_components());
}

#[test]
fn static_v1_fixture_loads() {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/fixtures/checkpoint_v1_figmn.json"
    );
    let text = std::fs::read_to_string(path).expect("fixture must exist");
    let loaded = Figmn::from_json(&parse(&text).unwrap()).expect("v1 fixture must load");
    assert_eq!(loaded.dim(), 2);
    assert_eq!(loaded.num_components(), 2);
    assert_eq!(loaded.points_seen(), 7);
    assert_eq!(loaded.component_mean(1), &[4.0, 4.0]);
    assert_eq!(loaded.component_stats(0), (1.5, 3));

    // Cross-check against the same mixture assembled through the
    // independent PackedState wire-format path (identity Λ, log|C|=0 —
    // every value exactly representable, so f32 round-trip is exact).
    let mut st = PackedState::empty(2, 2);
    for (j, (mean, sp, v)) in
        [([0.0f32, 0.0], 1.5f32, 3.0f32), ([4.0, 4.0], 2.5, 4.0)].iter().enumerate()
    {
        st.mus[j * 2] = mean[0];
        st.mus[j * 2 + 1] = mean[1];
        st.lambdas[j * 4] = 1.0;
        st.lambdas[j * 4 + 3] = 1.0;
        st.log_dets[j] = 0.0;
        st.sps[j] = *sp;
        st.vs[j] = *v;
        st.mask[j] = 1.0;
    }
    let cfg = GmmConfig::new(2).with_delta(0.5).with_beta(0.1).without_pruning();
    let twin = st.to_figmn(cfg, &[2.0, 2.0], 7);
    assert_eq!(twin.num_components(), 2);
    for x in [[0.5, -0.25], [3.5, 4.25], [2.0, 2.0]] {
        assert!(
            loaded.log_density(&x).to_bits() == twin.log_density(&x).to_bits(),
            "fixture scoring diverged from wire-format twin at {x:?}"
        );
        assert_eq!(loaded.posteriors(&x), twin.posteriors(&x));
        assert_eq!(
            loaded.predict(&x[..1], &[0], &[1]),
            twin.predict(&x[..1], &[0], &[1])
        );
    }
}
