//! Staged learn pipeline — the exactness and drift-adaptation contract.
//!
//! The mini-batch learn pipeline (`gmm::learn_pipeline`) stages B-point
//! blocks through a frozen distance pass + sequential update stage. Its
//! contract:
//!
//! - **`MiniBatch{b: 1}` with decay off is bit-identical to `Online`**
//!   at every engine thread count, for both kernel modes and both
//!   search modes — the degenerate block routes through the exact
//!   online body, so opting a model into the pipeline costs nothing
//!   until `b > 1`.
//! - **determinism within a block size**: for a fixed `b`, thread
//!   counts {1, 2, 4} reproduce the serial blocked path bit for bit
//!   (the K×B distance tile is sharded, the update stage is
//!   sequential).
//! - **TopC blocks are exact**: the masked union-row pass makes
//!   TopC×MiniBatch bit-identical to the TopC *per-point* path (not
//!   merely deterministic) at every thread count — including blocks
//!   where the χ²-fallback gate fires mid-block — and incremental
//!   index maintenance yields the same candidate sets as a freshly
//!   rebuilt index.
//! - **drift adaptation**: with exponential `sp` decay (and max-age
//!   eviction), a model recovers accuracy after an adversarial
//!   mean-swap shift, while a non-decayed model keeps voting its
//!   pre-shift mass — the `data::synth::drift_stream` scenario.

use figmn::data::synth::{drift_stream, DriftSpec};
use figmn::engine::EngineConfig;
use figmn::gmm::supervised::supervised_figmn;
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture, KernelMode, LearnMode, SearchMode};
use figmn::linalg::Matrix;
use figmn::rng::Pcg64;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// A stream that forces both creations and updates: clustered draws
/// around `k` well-separated centers.
fn clustered_stream(d: usize, k: usize, n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    let centers: Vec<Vec<f64>> =
        (0..k).map(|_| (0..d).map(|_| rng.normal() * 30.0).collect()).collect();
    (0..n)
        .map(|i| centers[i % k].iter().map(|&c| c + rng.normal() * 0.5).collect())
        .collect()
}

/// Full-state bitwise equality: arenas, scalars, and read surfaces.
fn assert_bit_identical(a: &Figmn, b: &Figmn, probes: &[Vec<f64>], tag: &str) {
    assert_eq!(a.num_components(), b.num_components(), "{tag}: K diverged");
    for j in 0..a.num_components() {
        assert_eq!(a.component_mean(j), b.component_mean(j), "{tag}: mean[{j}]");
        assert_eq!(a.store().mat(j), b.store().mat(j), "{tag}: lambda[{j}]");
        assert_eq!(a.component_log_det(j), b.component_log_det(j), "{tag}: log_det[{j}]");
        assert_eq!(a.component_stats(j), b.component_stats(j), "{tag}: sp/v[{j}]");
    }
    for (i, x) in probes.iter().enumerate() {
        assert_eq!(a.log_density(x), b.log_density(x), "{tag}: density[{i}]");
        assert_eq!(a.posteriors(x), b.posteriors(x), "{tag}: posteriors[{i}]");
    }
}

/// `MiniBatch{b: 1}` + decay off ≡ `Online`, bit for bit, across
/// {1, 2, 4} threads × {Strict, Fast} kernels × {Strict, TopC} search.
#[test]
fn minibatch_b1_decay_off_is_bit_identical_to_online() {
    let d = 16;
    let k = 48;
    let stream = clustered_stream(d, k, 400, 21);
    let probes = stream[..6].to_vec();
    let stds = vec![1.0; d];

    for kernel in [KernelMode::Strict, KernelMode::Fast] {
        for search in [SearchMode::Strict, SearchMode::TopC { c: 8 }] {
            let base = GmmConfig::new(d)
                .with_delta(1.0)
                .with_beta(0.05)
                .with_max_components(k)
                .with_kernel_mode(kernel)
                .with_search_mode(search)
                .without_pruning();

            let mut online = Figmn::new(base.clone(), &stds);
            let online_outcomes: Vec<_> = stream.iter().map(|x| online.learn(x)).collect();
            assert!(online.num_components() >= 2, "stream too tame");

            for t in THREAD_COUNTS {
                let cfg = base.clone().with_learn_mode(LearnMode::MiniBatch { b: 1 });
                let mut staged =
                    Figmn::new(cfg, &stds).with_engine(EngineConfig::new(t));
                let staged_outcomes = staged.learn_batch(&stream);
                let tag = format!("kernel={kernel} search={search} T={t}");
                assert_eq!(online_outcomes, staged_outcomes, "{tag}: outcomes");
                assert_bit_identical(&online, &staged, &probes, &tag);
            }
        }
    }
}

/// For a fixed block size `b > 1`, the staged pipeline is
/// thread-deterministic: pooled runs reproduce the serial blocked path
/// bit for bit (engine-sharded distance tiles, sequential updates).
#[test]
fn minibatch_blocks_bit_identical_across_thread_counts() {
    let d = 24;
    let k = 64;
    // K·D² well past the engine gate so the sharded tile path runs.
    let stream = clustered_stream(d, k, 600, 3);
    let probes = stream[..6].to_vec();
    let stds = vec![1.0; d];

    for kernel in [KernelMode::Strict, KernelMode::Fast] {
        let cfg = GmmConfig::new(d)
            .with_delta(1.0)
            .with_beta(0.05)
            .with_max_components(k)
            .with_kernel_mode(kernel)
            .with_learn_mode(LearnMode::MiniBatch { b: 8 })
            .without_pruning();

        let mut serial = Figmn::new(cfg.clone(), &stds);
        serial.learn_batch(&stream);
        assert_eq!(serial.num_components(), k);

        for t in THREAD_COUNTS {
            let mut pooled =
                Figmn::new(cfg.clone(), &stds).with_engine(EngineConfig::new(t));
            pooled.learn_batch(&stream);
            assert_bit_identical(&serial, &pooled, &probes, &format!("kernel={kernel} T={t}"));
        }
    }
}

/// TopC blocks stage through the masked union-row pass, which is
/// **bit-identical to the TopC per-point path** (not merely
/// deterministic): across kernel modes × c × threads {1, 2, 4} × block
/// sizes, a `MiniBatch{b}` TopC model ends bit-equal to an `Online`
/// TopC model fed the same stream — outcomes, arenas, read surfaces,
/// and index counters (the masked row count excepted; the per-point
/// path never streams union rows).
#[test]
fn topc_minibatch_is_bit_identical_to_topc_per_point() {
    let d = 16;
    let k = 48;
    let stream = clustered_stream(d, k, 400, 17);
    let probes = stream[..6].to_vec();
    let stds = vec![1.0; d];

    for kernel in [KernelMode::Strict, KernelMode::Fast] {
        for c in [4, 8] {
            let base = GmmConfig::new(d)
                .with_delta(1.0)
                .with_beta(0.05)
                .with_max_components(k)
                .with_kernel_mode(kernel)
                .with_search_mode(SearchMode::TopC { c })
                .without_pruning();

            let mut online = Figmn::new(base.clone(), &stds);
            let online_outcomes: Vec<_> = stream.iter().map(|x| online.learn(x)).collect();

            for b in [4, 8] {
                for t in THREAD_COUNTS {
                    let cfg = base.clone().with_learn_mode(LearnMode::MiniBatch { b });
                    let mut staged = Figmn::new(cfg, &stds).with_engine(EngineConfig::new(t));
                    let staged_outcomes = staged.learn_batch(&stream);
                    let tag = format!("kernel={kernel} c={c} b={b} T={t}");
                    assert_eq!(online_outcomes, staged_outcomes, "{tag}: outcomes");
                    assert_bit_identical(&online, &staged, &probes, &tag);
                    // The exact replay reproduces the per-point path's
                    // index trajectory event for event.
                    let (o, s) = (online.index_counters(), staged.index_counters());
                    assert_eq!(o.rebuilds, s.rebuilds, "{tag}: rebuilds");
                    assert_eq!(
                        o.incremental_updates, s.incremental_updates,
                        "{tag}: incremental updates"
                    );
                    assert_eq!(
                        o.fallback_gate_triggers, s.fallback_gate_triggers,
                        "{tag}: gate triggers"
                    );
                    assert!(s.masked_block_rows > 0, "{tag}: masked pass never ran");
                    assert_eq!(o.masked_block_rows, 0, "{tag}: online streamed union rows?");
                }
            }
        }
    }
}

/// A block engineered so the χ²-fallback gate fires (and *accepts*)
/// mid-block: a tight component shadows a wide one in Euclidean
/// ranking, so the mid-block probe's top-1 candidate fails χ² and only
/// the gate's exact sweep finds the accepting component. The blocked
/// path must replay that per-point decision — Updated, not Created —
/// and stay bitwise equal to the per-point path.
#[test]
fn fallback_gate_fires_mid_block_and_stays_exact() {
    let d = 2;
    let stds = vec![1.0; d];
    let base = GmmConfig::new(d)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_search_mode(SearchMode::TopC { c: 1 })
        .without_pruning();

    // Component A at (0, 2), trained tight: its χ² region shrinks far
    // below its Euclidean footprint.
    let mut stream: Vec<Vec<f64>> = vec![vec![0.0, 2.0]];
    let mut rng = Pcg64::seed(17);
    for _ in 0..22 {
        stream.push(vec![rng.normal() * 0.05, 2.0 + rng.normal() * 0.05]);
    }
    // Component B at (0, -6), trained with a widening spread along
    // dim 1 (each stage stays inside the current χ² region, so no
    // stage creates): B ends up reaching most of the way toward A.
    stream.push(vec![0.0, -6.0]);
    for &u in &[1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 4.5] {
        for _ in 0..2 {
            stream.push(vec![0.0, -6.0 + u]);
            stream.push(vec![0.0, -6.0 - u]);
        }
    }
    assert_eq!(stream.len() % 4, 0, "prefix must fill whole b=4 blocks");
    // The final block: the probe sits mid-block between A-updates.
    // Euclidean-nearest mean to the probe is A (3.0 vs ~5.0 away), but
    // only B's χ² region contains it — with c = 1 the candidate set is
    // {A}, so the decision rests entirely on the fallback gate.
    let probe_at = stream.len() + 1;
    stream.push(vec![0.02, 2.0]);
    stream.push(vec![0.0, -1.0]); // the probe
    stream.push(vec![-0.02, 2.0]);
    stream.push(vec![0.02, 1.98]);

    let mut online = Figmn::new(base.clone(), &stds);
    let online_outcomes: Vec<_> = stream.iter().map(|x| online.learn(x)).collect();
    assert_eq!(online.num_components(), 2, "construction drifted");
    assert_eq!(
        online_outcomes[probe_at],
        figmn::gmm::LearnOutcome::Updated,
        "construction drifted: the gate no longer rescues the probe"
    );
    assert!(online.index_counters().fallback_gate_triggers > 0);

    let mut staged =
        Figmn::new(base.with_learn_mode(LearnMode::MiniBatch { b: 4 }), &stds);
    let staged_outcomes = staged.learn_batch(&stream);
    assert_eq!(online_outcomes, staged_outcomes, "gate decision diverged in-block");
    assert_bit_identical(&online, &staged, &stream[..6].to_vec(), "mid-block gate");
    assert_eq!(
        online.index_counters().fallback_gate_triggers,
        staged.index_counters().fallback_gate_triggers,
        "blocked path must take the gate exactly as often"
    );
}

/// Create-churn: a stream where every point spawns a component. The
/// incremental maintenance contract says creates append into the index
/// (never rebuild it), and the maintained index answers queries exactly
/// like a freshly rebuilt one — checked against a checkpoint round-trip,
/// which rebuilds its index from scratch.
#[test]
fn create_churn_maintains_index_without_rebuilds() {
    let d = 8;
    let n = 96;
    let stds = vec![1.0; d];
    let cfg = GmmConfig::new(d)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_search_mode(SearchMode::TopC { c: 8 })
        .with_learn_mode(LearnMode::MiniBatch { b: 8 })
        .without_pruning();
    // Every point 1000σ from every other: all-create, zero updates.
    let stream: Vec<Vec<f64>> = (0..n)
        .map(|i| {
            let mut x = vec![0.0; d];
            x[i % d] = 1000.0 * (1 + i / d) as f64;
            x[(i + 1) % d] = 500.0 * (i % d) as f64;
            x
        })
        .collect();
    let mut m = Figmn::new(cfg, &stds);
    let outcomes = m.learn_batch(&stream);
    assert!(
        outcomes.iter().all(|o| *o == figmn::gmm::LearnOutcome::Created),
        "stream was supposed to be create-only"
    );
    let counters = m.index_counters();
    assert_eq!(counters.rebuilds, 0, "create churn must never trigger a full rebuild");
    assert_eq!(
        counters.incremental_updates,
        (n - 1) as u64,
        "every post-bootstrap create appends incrementally"
    );

    // Round-trip through a checkpoint: `from_json` rebuilds the index
    // from scratch. The maintained index must answer every read
    // identically — same candidate sets, same arithmetic.
    let rebuilt = Figmn::from_json(&m.to_json()).expect("checkpoint round-trip");
    for x in &stream {
        assert_eq!(m.log_density(x), rebuilt.log_density(x), "density diverged");
        assert_eq!(m.posteriors(x), rebuilt.posteriors(x), "posteriors diverged");
    }
}

/// Decay sweeps commute with blocking: a `MiniBatch{b}` model applies
/// `decay^B` at block start, so its sp mass stays finite and ordered
/// the same way as the online per-point sweep (exact equality is not
/// part of the contract for `b > 1`; boundedness and monotone aging
/// are).
#[test]
fn decayed_minibatch_sp_mass_stays_bounded() {
    let d = 8;
    let stream = clustered_stream(d, 4, 300, 5);
    let stds = vec![1.0; d];
    let cfg = GmmConfig::new(d)
        .with_delta(0.5)
        .with_beta(0.05)
        .with_learn_mode(LearnMode::MiniBatch { b: 8 })
        .with_decay(0.99)
        .without_pruning();
    let mut m = Figmn::new(cfg, &stds);
    m.learn_batch(&stream);
    // Geometric series bound: total sp mass under decay g is at most
    // K_created + 1/(1-g) in posterior mass units.
    let total_sp: f64 = (0..m.num_components()).map(|j| m.component_stats(j).0).sum();
    assert!(total_sp.is_finite() && total_sp > 0.0);
    // A decay-off run accumulates exactly one unit of sp mass per point
    // (300 here); the geometric sweep caps it near 1/(1 - 0.99) = 100.
    assert!(total_sp < 200.0, "decay failed to forget: total sp {total_sp}");
}

/// The drift story end to end: after an adversarial mean-swap shift,
/// the decayed + max-age model recovers post-shift accuracy while the
/// non-decayed model keeps voting its pre-shift mass.
#[test]
fn decay_recovers_accuracy_after_mean_swap_drift() {
    let spec = DriftSpec {
        dim: 6,
        classes: 2,
        instances: 4400,
        shift_at: 2000,
        shift: 0.0,
        swap_classes: true,
        cov_ramp: 1.5,
    };
    let data = drift_stream(&spec, 13);
    let stds = data.feature_stds();
    let train_n = 4000;

    let base = GmmConfig::new(1).with_delta(0.5).with_beta(0.05);
    let adaptive_cfg = base.clone().with_decay(0.995).with_max_age(1500);

    let mut adaptive = supervised_figmn(adaptive_cfg, &stds, spec.classes);
    let mut stale = supervised_figmn(base, &stds, spec.classes);
    adaptive.train_batch(&data.features[..train_n], &data.labels[..train_n]);
    stale.train_batch(&data.features[..train_n], &data.labels[..train_n]);

    let accuracy = |clf: &figmn::gmm::supervised::SupervisedGmm<Figmn>| -> f64 {
        let scores = clf.class_scores_batch(&data.features[train_n..]);
        scores
            .iter()
            .zip(&data.labels[train_n..])
            .filter(|(s, &t)| {
                s.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
                    == t
            })
            .count() as f64
            / (data.features.len() - train_n) as f64
    };
    let acc_adaptive = accuracy(&adaptive);
    let acc_stale = accuracy(&stale);
    assert!(
        acc_adaptive >= 0.8,
        "decayed model failed to recover after the swap: acc {acc_adaptive}"
    );
    assert!(
        acc_adaptive >= acc_stale + 0.15,
        "decay bought nothing: adaptive {acc_adaptive} vs stale {acc_stale}"
    );
}

/// Keep the linalg import honest (`store().mat` returns the packed
/// slice; densify one to check symmetry survives block updates).
#[test]
fn blocked_updates_preserve_packed_symmetry() {
    let d = 6;
    let stream = clustered_stream(d, 4, 120, 9);
    let stds = vec![1.0; d];
    let cfg = GmmConfig::new(d)
        .with_delta(0.5)
        .with_beta(0.05)
        .with_learn_mode(LearnMode::MiniBatch { b: 8 })
        .without_pruning();
    let mut m = Figmn::new(cfg, &stds);
    m.learn_batch(&stream);
    for j in 0..m.num_components() {
        let lam: Matrix = m.store().mat_dense(j);
        for r in 0..d {
            for c in 0..r {
                assert_eq!(lam[(r, c)], lam[(c, r)], "lambda[{j}] asymmetric at ({r},{c})");
            }
        }
    }
}
