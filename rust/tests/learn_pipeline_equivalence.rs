//! Staged learn pipeline — the exactness and drift-adaptation contract.
//!
//! The mini-batch learn pipeline (`gmm::learn_pipeline`) stages B-point
//! blocks through a frozen distance pass + sequential update stage. Its
//! contract:
//!
//! - **`MiniBatch{b: 1}` with decay off is bit-identical to `Online`**
//!   at every engine thread count, for both kernel modes and both
//!   search modes — the degenerate block routes through the exact
//!   online body, so opting a model into the pipeline costs nothing
//!   until `b > 1`.
//! - **determinism within a block size**: for a fixed `b`, thread
//!   counts {1, 2, 4} reproduce the serial blocked path bit for bit
//!   (the K×B distance tile is sharded, the update stage is
//!   sequential).
//! - **drift adaptation**: with exponential `sp` decay (and max-age
//!   eviction), a model recovers accuracy after an adversarial
//!   mean-swap shift, while a non-decayed model keeps voting its
//!   pre-shift mass — the `data::synth::drift_stream` scenario.

use figmn::data::synth::{drift_stream, DriftSpec};
use figmn::engine::EngineConfig;
use figmn::gmm::supervised::supervised_figmn;
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture, KernelMode, LearnMode, SearchMode};
use figmn::linalg::Matrix;
use figmn::rng::Pcg64;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// A stream that forces both creations and updates: clustered draws
/// around `k` well-separated centers.
fn clustered_stream(d: usize, k: usize, n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    let centers: Vec<Vec<f64>> =
        (0..k).map(|_| (0..d).map(|_| rng.normal() * 30.0).collect()).collect();
    (0..n)
        .map(|i| centers[i % k].iter().map(|&c| c + rng.normal() * 0.5).collect())
        .collect()
}

/// Full-state bitwise equality: arenas, scalars, and read surfaces.
fn assert_bit_identical(a: &Figmn, b: &Figmn, probes: &[Vec<f64>], tag: &str) {
    assert_eq!(a.num_components(), b.num_components(), "{tag}: K diverged");
    for j in 0..a.num_components() {
        assert_eq!(a.component_mean(j), b.component_mean(j), "{tag}: mean[{j}]");
        assert_eq!(a.store().mat(j), b.store().mat(j), "{tag}: lambda[{j}]");
        assert_eq!(a.component_log_det(j), b.component_log_det(j), "{tag}: log_det[{j}]");
        assert_eq!(a.component_stats(j), b.component_stats(j), "{tag}: sp/v[{j}]");
    }
    for (i, x) in probes.iter().enumerate() {
        assert_eq!(a.log_density(x), b.log_density(x), "{tag}: density[{i}]");
        assert_eq!(a.posteriors(x), b.posteriors(x), "{tag}: posteriors[{i}]");
    }
}

/// `MiniBatch{b: 1}` + decay off ≡ `Online`, bit for bit, across
/// {1, 2, 4} threads × {Strict, Fast} kernels × {Strict, TopC} search.
#[test]
fn minibatch_b1_decay_off_is_bit_identical_to_online() {
    let d = 16;
    let k = 48;
    let stream = clustered_stream(d, k, 400, 21);
    let probes = stream[..6].to_vec();
    let stds = vec![1.0; d];

    for kernel in [KernelMode::Strict, KernelMode::Fast] {
        for search in [SearchMode::Strict, SearchMode::TopC { c: 8 }] {
            let base = GmmConfig::new(d)
                .with_delta(1.0)
                .with_beta(0.05)
                .with_max_components(k)
                .with_kernel_mode(kernel)
                .with_search_mode(search)
                .without_pruning();

            let mut online = Figmn::new(base.clone(), &stds);
            let online_outcomes: Vec<_> = stream.iter().map(|x| online.learn(x)).collect();
            assert!(online.num_components() >= 2, "stream too tame");

            for t in THREAD_COUNTS {
                let cfg = base.clone().with_learn_mode(LearnMode::MiniBatch { b: 1 });
                let mut staged =
                    Figmn::new(cfg, &stds).with_engine(EngineConfig::new(t));
                let staged_outcomes = staged.learn_batch(&stream);
                let tag = format!("kernel={kernel} search={search} T={t}");
                assert_eq!(online_outcomes, staged_outcomes, "{tag}: outcomes");
                assert_bit_identical(&online, &staged, &probes, &tag);
            }
        }
    }
}

/// For a fixed block size `b > 1`, the staged pipeline is
/// thread-deterministic: pooled runs reproduce the serial blocked path
/// bit for bit (engine-sharded distance tiles, sequential updates).
#[test]
fn minibatch_blocks_bit_identical_across_thread_counts() {
    let d = 24;
    let k = 64;
    // K·D² well past the engine gate so the sharded tile path runs.
    let stream = clustered_stream(d, k, 600, 3);
    let probes = stream[..6].to_vec();
    let stds = vec![1.0; d];

    for kernel in [KernelMode::Strict, KernelMode::Fast] {
        let cfg = GmmConfig::new(d)
            .with_delta(1.0)
            .with_beta(0.05)
            .with_max_components(k)
            .with_kernel_mode(kernel)
            .with_learn_mode(LearnMode::MiniBatch { b: 8 })
            .without_pruning();

        let mut serial = Figmn::new(cfg.clone(), &stds);
        serial.learn_batch(&stream);
        assert_eq!(serial.num_components(), k);

        for t in THREAD_COUNTS {
            let mut pooled =
                Figmn::new(cfg.clone(), &stds).with_engine(EngineConfig::new(t));
            pooled.learn_batch(&stream);
            assert_bit_identical(&serial, &pooled, &probes, &format!("kernel={kernel} T={t}"));
        }
    }
}

/// TopC models never stage blocks (the exact fallback gate is
/// per-point): `MiniBatch{b: 8}` under TopC is bit-identical to
/// `Online` under TopC, not merely deterministic.
#[test]
fn topc_blocks_route_through_exact_online_path() {
    let d = 16;
    let stream = clustered_stream(d, 32, 400, 17);
    let stds = vec![1.0; d];
    let base = GmmConfig::new(d)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_max_components(32)
        .with_search_mode(SearchMode::TopC { c: 4 })
        .without_pruning();

    let mut online = Figmn::new(base.clone(), &stds);
    for x in &stream {
        online.learn(x);
    }
    let mut staged =
        Figmn::new(base.with_learn_mode(LearnMode::MiniBatch { b: 8 }), &stds);
    staged.learn_batch(&stream);
    assert_bit_identical(&online, &staged, &stream[..6].to_vec(), "topc b=8");
}

/// Decay sweeps commute with blocking: a `MiniBatch{b}` model applies
/// `decay^B` at block start, so its sp mass stays finite and ordered
/// the same way as the online per-point sweep (exact equality is not
/// part of the contract for `b > 1`; boundedness and monotone aging
/// are).
#[test]
fn decayed_minibatch_sp_mass_stays_bounded() {
    let d = 8;
    let stream = clustered_stream(d, 4, 300, 5);
    let stds = vec![1.0; d];
    let cfg = GmmConfig::new(d)
        .with_delta(0.5)
        .with_beta(0.05)
        .with_learn_mode(LearnMode::MiniBatch { b: 8 })
        .with_decay(0.99)
        .without_pruning();
    let mut m = Figmn::new(cfg, &stds);
    m.learn_batch(&stream);
    // Geometric series bound: total sp mass under decay g is at most
    // K_created + 1/(1-g) in posterior mass units.
    let total_sp: f64 = (0..m.num_components()).map(|j| m.component_stats(j).0).sum();
    assert!(total_sp.is_finite() && total_sp > 0.0);
    // A decay-off run accumulates exactly one unit of sp mass per point
    // (300 here); the geometric sweep caps it near 1/(1 - 0.99) = 100.
    assert!(total_sp < 200.0, "decay failed to forget: total sp {total_sp}");
}

/// The drift story end to end: after an adversarial mean-swap shift,
/// the decayed + max-age model recovers post-shift accuracy while the
/// non-decayed model keeps voting its pre-shift mass.
#[test]
fn decay_recovers_accuracy_after_mean_swap_drift() {
    let spec = DriftSpec {
        dim: 6,
        classes: 2,
        instances: 4400,
        shift_at: 2000,
        shift: 0.0,
        swap_classes: true,
        cov_ramp: 1.5,
    };
    let data = drift_stream(&spec, 13);
    let stds = data.feature_stds();
    let train_n = 4000;

    let base = GmmConfig::new(1).with_delta(0.5).with_beta(0.05);
    let adaptive_cfg = base.clone().with_decay(0.995).with_max_age(1500);

    let mut adaptive = supervised_figmn(adaptive_cfg, &stds, spec.classes);
    let mut stale = supervised_figmn(base, &stds, spec.classes);
    adaptive.train_batch(&data.features[..train_n], &data.labels[..train_n]);
    stale.train_batch(&data.features[..train_n], &data.labels[..train_n]);

    let accuracy = |clf: &figmn::gmm::supervised::SupervisedGmm<Figmn>| -> f64 {
        let scores = clf.class_scores_batch(&data.features[train_n..]);
        scores
            .iter()
            .zip(&data.labels[train_n..])
            .filter(|(s, &t)| {
                s.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
                    == t
            })
            .count() as f64
            / (data.features.len() - train_n) as f64
    };
    let acc_adaptive = accuracy(&adaptive);
    let acc_stale = accuracy(&stale);
    assert!(
        acc_adaptive >= 0.8,
        "decayed model failed to recover after the swap: acc {acc_adaptive}"
    );
    assert!(
        acc_adaptive >= acc_stale + 0.15,
        "decay bought nothing: adaptive {acc_adaptive} vs stale {acc_stale}"
    );
}

/// Keep the linalg import honest (`store().mat` returns the packed
/// slice; densify one to check symmetry survives block updates).
#[test]
fn blocked_updates_preserve_packed_symmetry() {
    let d = 6;
    let stream = clustered_stream(d, 4, 120, 9);
    let stds = vec![1.0; d];
    let cfg = GmmConfig::new(d)
        .with_delta(0.5)
        .with_beta(0.05)
        .with_learn_mode(LearnMode::MiniBatch { b: 8 })
        .without_pruning();
    let mut m = Figmn::new(cfg, &stds);
    m.learn_batch(&stream);
    for j in 0..m.num_components() {
        let lam: Matrix = m.store().mat_dense(j);
        for r in 0..d {
            for c in 0..r {
                assert_eq!(lam[(r, c)], lam[(c, r)], "lambda[{j}] asymmetric at ({r},{c})");
            }
        }
    }
}
