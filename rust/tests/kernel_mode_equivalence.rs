//! Strict ≡ Fast kernel-mode equivalence — the dual-mode contract.
//!
//! A model configured with `KernelMode::Fast` runs blocked
//! SIMD-friendly variants of the three hot packed kernels instead of
//! the bit-identical scalar reference. The contract
//! (`linalg::KernelMode`):
//!
//! - **tolerance equivalence**: on the paper's Table 1 streams, a
//!   fast-mode model's log-densities track the strict model's to
//!   relative 1e-12, with the same discovered structure (same
//!   create/update decisions, same K);
//! - **determinism within a mode**: for a fixed mode, every engine
//!   thread count reproduces the serial path bit for bit;
//! - **checkpoint portability**: fast-trained checkpoints round-trip
//!   their mode, and readers that drop the additive `kernel_mode`
//!   field still load the document (defaulting to Strict) and score
//!   within the same tolerance.

use figmn::data::synth;
use figmn::engine::EngineConfig;
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture, KernelMode};
use figmn::json::parse;
use figmn::rng::Pcg64;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// Table 1 streams: fast mode discovers the same mixture as strict
/// mode and scores within relative 1e-12.
#[test]
fn table1_streams_fast_tracks_strict_to_1e12() {
    for name in ["iris", "Glass", "ionosphere"] {
        let spec = synth::spec(name).unwrap();
        let data = synth::generate(spec, 7);
        let stds = data.feature_stds();
        let strict_cfg = GmmConfig::new(data.dim())
            .with_delta(0.1)
            .with_beta(0.1)
            .with_max_components(64)
            .without_pruning();
        let fast_cfg = strict_cfg.clone().with_kernel_mode(KernelMode::Fast);

        let mut strict = Figmn::new(strict_cfg, &stds);
        let mut fast = Figmn::new(fast_cfg, &stds);
        for (step, x) in data.features.iter().enumerate() {
            assert_eq!(
                strict.learn(x),
                fast.learn(x),
                "{name}: create/update decisions diverged at step {step}"
            );
        }
        assert_eq!(strict.num_components(), fast.num_components(), "{name}: K diverged");
        assert!(strict.num_components() >= 2, "{name}: stream too tame");

        let mut rng = Pcg64::seed(11);
        for i in 0..20 {
            let x: Vec<f64> =
                (0..data.dim()).map(|_| rng.normal() * 2.0).collect();
            let a = strict.log_density(&x);
            let b = fast.log_density(&x);
            assert!(
                rel_close(a, b, 1e-12),
                "{name}: log_density[{i}] diverged past 1e-12 ({a} vs {b})"
            );
            // Batch scoring runs the same mode-aware kernels.
            assert_eq!(fast.score_batch(&[x.clone()])[0], b, "{name}: batch != serial");
        }
        // Component state tracks too (the update kernel's tolerance).
        for j in 0..strict.num_components() {
            for (a, b) in strict
                .component_mean(j)
                .iter()
                .zip(fast.component_mean(j).iter())
            {
                assert!(rel_close(*a, *b, 1e-9), "{name}: mean[{j}] diverged");
            }
            assert!(
                rel_close(strict.component_log_det(j), fast.component_log_det(j), 1e-9),
                "{name}: log_det[{j}] diverged"
            );
        }
    }
}

/// Fast mode keeps the crate's determinism guarantee *within the
/// mode*: thread counts {1, 2, 4} reproduce the serial fast path bit
/// for bit, including snapshot scoring.
#[test]
fn fast_mode_bit_identical_across_thread_counts() {
    let d = 24;
    let k_cap = 64;
    let mut rng = Pcg64::seed(3);
    let centers: Vec<Vec<f64>> =
        (0..k_cap).map(|_| (0..d).map(|_| rng.normal() * 30.0).collect()).collect();
    let stream: Vec<Vec<f64>> = (0..600)
        .map(|i| centers[i % k_cap].iter().map(|&c| c + rng.normal() * 0.5).collect())
        .collect();
    let cfg = GmmConfig::new(d)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_max_components(k_cap)
        .with_kernel_mode(KernelMode::Fast)
        .without_pruning();
    let stds = vec![1.0; d];

    let mut serial = Figmn::new(cfg.clone(), &stds);
    for x in &stream {
        serial.learn(x);
    }
    // K·D² = 64·576 ≫ the engine gate: the sharded fast path really runs.
    assert_eq!(serial.num_components(), k_cap);
    let probes: Vec<Vec<f64>> = stream[..8].to_vec();
    let snap = serial.snapshot();

    for t in THREAD_COUNTS {
        let mut pooled =
            Figmn::new(cfg.clone(), &stds).with_engine(EngineConfig::new(t));
        pooled.learn_batch(&stream);
        assert_eq!(serial.num_components(), pooled.num_components(), "T={t}: K");
        for j in 0..serial.num_components() {
            assert_eq!(serial.component_mean(j), pooled.component_mean(j), "T={t}: mean[{j}]");
            assert_eq!(serial.store().mat(j), pooled.store().mat(j), "T={t}: lambda[{j}]");
            assert_eq!(
                serial.component_log_det(j),
                pooled.component_log_det(j),
                "T={t}: log_det[{j}]"
            );
            assert_eq!(serial.component_stats(j), pooled.component_stats(j), "T={t}: sp/v[{j}]");
        }
        for (i, x) in probes.iter().enumerate() {
            assert_eq!(serial.log_density(x), pooled.log_density(x), "T={t}: density[{i}]");
            assert_eq!(serial.posteriors(x), pooled.posteriors(x), "T={t}: posteriors[{i}]");
            // The snapshot runs the source model's mode, so it matches
            // the serial fast path bit for bit.
            assert_eq!(snap.log_density(x), serial.log_density(x), "snapshot density[{i}]");
        }
        assert_eq!(serial.score_batch(&probes), pooled.score_batch(&probes), "T={t}: batch");
    }
}

/// Fast-trained checkpoints load everywhere: the mode round-trips, and
/// a reader that drops the additive field still loads the document and
/// scores within the fast-mode tolerance.
#[test]
fn fast_checkpoints_round_trip_and_degrade_gracefully() {
    let spec = synth::spec("iris").unwrap();
    let data = synth::generate(spec, 5);
    let stds = data.feature_stds();
    let cfg = GmmConfig::new(data.dim())
        .with_delta(0.2)
        .with_beta(0.1)
        .with_kernel_mode(KernelMode::Fast)
        .without_pruning();
    let mut m = Figmn::new(cfg, &stds);
    for x in &data.features {
        m.learn(x);
    }

    let text = m.to_json().to_string_compact();
    assert!(text.contains("\"kernel_mode\":\"fast\""), "v2 must carry the mode");

    // Same-version reader: mode preserved, scoring bit-identical.
    let restored = Figmn::from_json(&parse(&text).unwrap()).unwrap();
    assert_eq!(restored.config().kernel_mode, KernelMode::Fast);
    let mut rng = Pcg64::seed(9);
    for _ in 0..10 {
        let x: Vec<f64> = (0..data.dim()).map(|_| rng.normal() * 2.0).collect();
        assert_eq!(m.log_density(&x), restored.log_density(&x));
    }

    // A reader that ignores/drops the field (the pre-dual-mode format)
    // still loads the same arenas — Strict by default — and scores
    // within the tolerance contract.
    let stripped = text.replace("\"kernel_mode\":\"fast\",", "");
    assert!(!stripped.contains("kernel_mode"));
    let as_strict = Figmn::from_json(&parse(&stripped).unwrap()).unwrap();
    assert_eq!(as_strict.config().kernel_mode, KernelMode::Strict);
    assert_eq!(as_strict.num_components(), m.num_components());
    for _ in 0..10 {
        let x: Vec<f64> = (0..data.dim()).map(|_| rng.normal() * 2.0).collect();
        let a = m.log_density(&x);
        let b = as_strict.log_density(&x);
        assert!(
            rel_close(a, b, 1e-12),
            "strict reader of fast checkpoint diverged ({a} vs {b})"
        );
    }
}
