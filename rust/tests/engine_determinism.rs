//! Engine determinism property tests (the crate's headline guarantee):
//! on the paper's Table 1 synthetic streams, `Figmn` must produce
//! **bit-identical** components, log-dets, posteriors, and predictions
//! for thread counts {1, 2, 4} (and the serial no-engine path), and the
//! sharded `Figmn` must still match `Igmn` within the paper's §4
//! equivalence tolerance.

use figmn::data::synth;
use figmn::engine::EngineConfig;
use figmn::gmm::{Figmn, GmmConfig, Igmn, IncrementalMixture};
use figmn::rng::Pcg64;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

fn figmn_with_threads(cfg: &GmmConfig, stds: &[f64], threads: Option<usize>) -> Figmn {
    let mut m = Figmn::new(cfg.clone(), stds);
    if let Some(t) = threads {
        m.set_engine(Some(EngineConfig::new(t)));
    }
    m
}

/// Bitwise equality of full model state plus probe-point outputs.
fn assert_bit_identical(a: &Figmn, b: &Figmn, probes: &[Vec<f64>], tag: &str) {
    assert_eq!(a.num_components(), b.num_components(), "{tag}: K");
    for j in 0..a.num_components() {
        assert_eq!(a.component_mean(j), b.component_mean(j), "{tag}: mean[{j}]");
        assert_eq!(
            a.component_lambda(j).as_slice(),
            b.component_lambda(j).as_slice(),
            "{tag}: lambda[{j}]"
        );
        assert!(
            a.component_log_det(j) == b.component_log_det(j),
            "{tag}: log_det[{j}] {} vs {}",
            a.component_log_det(j),
            b.component_log_det(j)
        );
        assert_eq!(a.component_stats(j), b.component_stats(j), "{tag}: sp/v[{j}]");
    }
    for (i, x) in probes.iter().enumerate() {
        assert_eq!(a.posteriors(x), b.posteriors(x), "{tag}: posteriors[{i}]");
        assert!(
            a.log_density(x) == b.log_density(x),
            "{tag}: log_density[{i}]"
        );
        let d = a.dim();
        let known: Vec<usize> = (0..d - 1).collect();
        assert_eq!(
            a.predict(&x[..d - 1], &known, &[d - 1]),
            b.predict(&x[..d - 1], &known, &[d - 1]),
            "{tag}: predict[{i}]"
        );
    }
    // Batch entry points agree with each other too.
    assert_eq!(a.score_batch(probes), b.score_batch(probes), "{tag}: score_batch");
}

/// Table 1 streams → every thread count produces the serial model, bit
/// for bit.
#[test]
fn table1_streams_bit_identical_across_thread_counts() {
    for name in ["iris", "Glass", "ionosphere"] {
        let spec = synth::spec(name).unwrap();
        let data = synth::generate(spec, 7);
        let stds = data.feature_stds();
        // Growth-friendly config so K climbs well past the parallel-work
        // gate on the wider datasets.
        let cfg = GmmConfig::new(data.dim())
            .with_delta(0.1)
            .with_beta(0.1)
            .with_max_components(64)
            .without_pruning();

        let mut serial = figmn_with_threads(&cfg, &stds, None);
        for x in &data.features {
            serial.learn(x);
        }
        assert!(serial.num_components() >= 2, "{name}: stream too tame");

        let mut rng = Pcg64::seed(11);
        let probes: Vec<Vec<f64>> = (0..10)
            .map(|_| (0..data.dim()).map(|_| rng.normal() * 2.0).collect())
            .collect();

        for t in THREAD_COUNTS {
            let mut pooled = figmn_with_threads(&cfg, &stds, Some(t));
            // Exercise the batch learn path on the engine side.
            pooled.learn_batch(&data.features);
            assert_bit_identical(&serial, &pooled, &probes, &format!("{name} T={t}"));
        }
    }
}

/// A wide high-K stream (K ≈ 64, D = 24) that is guaranteed to cross the
/// engine's parallel-work gate, so the pool demonstrably runs.
#[test]
fn high_k_stream_bit_identical_and_gate_crossed() {
    let d = 24;
    let k_cap = 64;
    let mut rng = Pcg64::seed(3);
    let centers: Vec<Vec<f64>> =
        (0..k_cap).map(|_| (0..d).map(|_| rng.normal() * 30.0).collect()).collect();
    let stream: Vec<Vec<f64>> = (0..600)
        .map(|i| centers[i % k_cap].iter().map(|&c| c + rng.normal() * 0.5).collect())
        .collect();
    let cfg = GmmConfig::new(d)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_max_components(k_cap)
        .without_pruning();
    let stds = vec![1.0; d];

    let mut serial = Figmn::new(cfg.clone(), &stds);
    for x in &stream {
        serial.learn(x);
    }
    // K·D² = 64·576 ≫ the gate threshold: the sharded path really ran.
    assert_eq!(serial.num_components(), k_cap);

    let probes: Vec<Vec<f64>> = stream[..8].to_vec();
    for t in THREAD_COUNTS {
        let mut pooled = Figmn::new(cfg.clone(), &stds).with_engine(EngineConfig::new(t));
        pooled.learn_batch(&stream);
        assert_bit_identical(&serial, &pooled, &probes, &format!("high-K T={t}"));
        // predict_batch through the pool matches per-point predict.
        let known: Vec<usize> = (0..d - 1).collect();
        let kvs: Vec<Vec<f64>> = probes.iter().map(|x| x[..d - 1].to_vec()).collect();
        let batch = pooled.predict_batch(&kvs, &known, &[d - 1]);
        for (kv, b) in kvs.iter().zip(batch.iter()) {
            assert_eq!(&serial.predict(kv, &known, &[d - 1]), b, "predict_batch T={t}");
        }
    }
}

/// The sharded fast model still matches the covariance baseline within
/// the paper's §4 equivalence tolerance.
#[test]
fn sharded_figmn_matches_igmn_within_paper_tolerance() {
    let rel = |a: f64, b: f64| {
        let scale = a.abs().max(b.abs()).max(1e-300);
        (a - b).abs() / scale
    };
    for name in ["iris", "Glass"] {
        let spec = synth::spec(name).unwrap();
        let data = synth::generate(spec, 13);
        let stds = data.feature_stds();
        let cfg = GmmConfig::new(data.dim()).with_delta(0.5).with_beta(0.05).without_pruning();

        let mut fast = Figmn::new(cfg.clone(), &stds).with_engine(EngineConfig::new(4));
        let mut slow = Igmn::new(cfg, &stds).with_engine(EngineConfig::new(2));
        for (step, x) in data.features.iter().enumerate() {
            let a = fast.learn(x);
            let b = slow.learn(x);
            assert_eq!(a, b, "{name}: create/update diverged at step {step}");
        }
        assert_eq!(fast.num_components(), slow.num_components(), "{name}");

        for j in 0..fast.num_components() {
            for (u, v) in fast.component_mean(j).iter().zip(slow.component_mean(j).iter()) {
                assert!(rel(*u, *v) < 1e-6, "{name}: mean[{j}] {u} vs {v}");
            }
            let (sp_a, v_a) = fast.component_stats(j);
            let (sp_b, v_b) = slow.component_stats(j);
            assert!(rel(sp_a, sp_b) < 1e-6, "{name}: sp[{j}]");
            assert_eq!(v_a, v_b, "{name}: v[{j}]");
        }
        let mut rng = Pcg64::seed(29);
        for _ in 0..10 {
            let x: Vec<f64> = (0..data.dim()).map(|_| rng.normal() * 2.0).collect();
            assert!(
                rel(fast.log_density(&x), slow.log_density(&x)) < 1e-6,
                "{name}: log_density"
            );
            for (u, v) in fast.posteriors(&x).iter().zip(slow.posteriors(&x).iter()) {
                assert!((u - v).abs() < 1e-6, "{name}: posterior {u} vs {v}");
            }
        }
    }
}
