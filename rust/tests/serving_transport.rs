//! Event-loop serving transport: framing bounds, slow clients,
//! race-free shutdown, coalescing deadline semantics, and the
//! bitwise coalesced⇄per-request contract — all over real sockets.

use figmn::coordinator::protocol::{Request, Response};
use figmn::coordinator::server::dispatch;
use figmn::coordinator::{
    serve, BatcherConfig, Metrics, ModelSpec, Registry, Server, ServerConfig,
};
use figmn::gmm::{GmmConfig, KernelMode, SearchMode};
use figmn::rng::Pcg64;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn client(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
    // A wildcard-bound listener reports 0.0.0.0; connect via loopback.
    let target = if addr.ip().is_unspecified() {
        std::net::SocketAddr::new("127.0.0.1".parse().unwrap(), addr.port())
    } else {
        addr
    };
    let stream = TcpStream::connect(target).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (reader, stream)
}

fn roundtrip(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    req: &Request,
) -> Response {
    let mut line = req.to_json().to_string_compact();
    line.push('\n');
    writer.write_all(line.as_bytes()).unwrap();
    let mut buf = String::new();
    reader.read_line(&mut buf).unwrap();
    Response::from_line(&buf).unwrap()
}

fn read_response(reader: &mut BufReader<TcpStream>) -> Response {
    let mut buf = String::new();
    reader.read_line(&mut buf).unwrap();
    Response::from_line(&buf).unwrap()
}

/// A trained 2-feature / 2-class model named "m" with a published
/// snapshot covering all 64 learned points.
fn trained_registry() -> Arc<Registry> {
    trained_registry_with("m", 2, KernelMode::Strict, SearchMode::Strict)
}

fn trained_registry_with(
    name: &str,
    n_features: usize,
    kernel: KernelMode,
    search: SearchMode,
) -> Arc<Registry> {
    let registry = Arc::new(Registry::new(Arc::new(Metrics::new())));
    let gmm = GmmConfig::new(1)
        .with_delta(0.5)
        .with_beta(0.05)
        .without_pruning()
        .with_kernel_mode(kernel)
        .with_search_mode(search);
    registry
        .create(
            ModelSpec::new(name, n_features, 2)
                .with_gmm(gmm)
                .with_stds(vec![3.0; n_features])
                .with_snapshot_interval(8),
        )
        .unwrap();
    let router = registry.router(name).unwrap();
    let mut rng = Pcg64::seed(11);
    for i in 0..64 {
        let c = i % 2;
        let mut x = vec![c as f64 * 6.0 + rng.normal() * 0.5];
        for _ in 1..n_features {
            x.push(rng.normal() * 0.5);
        }
        router.learn(x, c).unwrap();
    }
    // Drain the worker queue, then wait until the snapshot covers the
    // full prefix (64 is a multiple of the interval).
    registry.stats(name).unwrap();
    router.shards()[0]
        .wait_snapshot_points(64, 5000)
        .expect("snapshot never published");
    registry
}

/// Joint vector (features + one-hot class block) for the 2-feature
/// model.
fn joint(a: f64, b: f64, class: usize) -> Vec<f64> {
    let mut x = vec![a, b, 0.0, 0.0];
    x[2 + class] = 1.0;
    x
}

#[test]
fn oversized_request_line_is_rejected_then_conn_recovers() {
    let registry = trained_registry();
    let cfg = ServerConfig { max_line_bytes: 1024, ..ServerConfig::default() };
    let server = serve(registry, cfg).unwrap();
    let (mut reader, mut writer) = client(server.local_addr);

    // 5000 bytes without a newline blow the 1 KiB cap mid-line…
    let big = vec![b'a'; 5000];
    writer.write_all(&big).unwrap();
    writer.write_all(b"\n").unwrap();
    // …and the connection must resynchronize at the newline: the next
    // request parses normally.
    let mut line = Request::Ping.to_json().to_string_compact();
    line.push('\n');
    writer.write_all(line.as_bytes()).unwrap();

    match read_response(&mut reader) {
        Response::Error(e) => {
            assert!(e.contains("exceeds"), "unexpected error text: {e}")
        }
        other => panic!("expected protocol error, got {other:?}"),
    }
    assert_eq!(read_response(&mut reader), Response::Pong);
    server.shutdown();
}

#[test]
fn split_line_request_is_reassembled() {
    let registry = trained_registry();
    let server = serve(registry, ServerConfig::default()).unwrap();
    let (mut reader, mut writer) = client(server.local_addr);

    // One request split across two writes with a pause between them,
    // pipelined with a second complete request in the same final write.
    writer.write_all(b"{\"op\":\"pi").unwrap();
    writer.flush().unwrap();
    std::thread::sleep(Duration::from_millis(30));
    writer.write_all(b"ng\"}\n{\"op\":\"ping\"}\n").unwrap();

    assert_eq!(read_response(&mut reader), Response::Pong);
    assert_eq!(read_response(&mut reader), Response::Pong);
    server.shutdown();
}

#[test]
fn slowloris_client_does_not_stall_others_or_shutdown() {
    let registry = trained_registry();
    // One driver: the stalled socket and the healthy one share the same
    // event loop thread — the strongest version of the claim.
    let cfg = ServerConfig { drivers: 1, ..ServerConfig::default() };
    let server = serve(registry, cfg).unwrap();

    // The slowloris peer trickles a never-completed request line.
    let (_slow_reader, mut slow) = client(server.local_addr);
    slow.write_all(b"{\"op\":\"sc").unwrap();
    slow.flush().unwrap();

    // A healthy client must keep getting served promptly.
    let (mut reader, mut writer) = client(server.local_addr);
    let t0 = Instant::now();
    for _ in 0..50 {
        assert_eq!(roundtrip(&mut reader, &mut writer, &Request::Ping), Response::Pong);
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "healthy client stalled behind slowloris: {:?}",
        t0.elapsed()
    );

    // Trickle a few more bytes so the slow connection is mid-line at
    // shutdown time, then prove shutdown still completes on deadline.
    slow.write_all(b"ore\",\"model").unwrap();
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        server.shutdown();
        done_tx.send(()).unwrap();
    });
    done_rx
        .recv_timeout(Duration::from_secs(5))
        .expect("shutdown stalled behind a slow client");
}

#[test]
fn shutdown_within_deadline_bound_to_wildcard() {
    let registry = trained_registry();
    // The legacy server's shutdown poke (TcpStream::connect(local_addr))
    // was racy for 0.0.0.0 binds; the wake pair must not care.
    let cfg = ServerConfig { addr: "0.0.0.0:0".into(), ..ServerConfig::default() };
    let server = serve(registry.clone(), cfg).unwrap();
    assert!(server.local_addr.ip().is_unspecified());
    let (mut reader, mut writer) = client(server.local_addr);
    assert_eq!(roundtrip(&mut reader, &mut writer, &Request::Ping), Response::Pong);
    let _idle = client(server.local_addr);

    let t0 = Instant::now();
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        server.shutdown();
        done_tx.send(()).unwrap();
    });
    done_rx
        .recv_timeout(Duration::from_secs(5))
        .expect("shutdown missed its deadline on a wildcard bind");
    assert!(t0.elapsed() < Duration::from_secs(5));
    // Every driver joined ⇒ no thread still holds the registry.
    assert_eq!(Arc::strong_count(&registry), 1, "a driver outlived shutdown");
}

#[test]
fn lone_coalesced_read_flushes_within_max_delay() {
    let registry = trained_registry();
    let cfg = ServerConfig {
        batch: BatcherConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(100),
        },
        ..ServerConfig::default()
    };
    let server = serve(registry.clone(), cfg).unwrap();
    let (mut reader, mut writer) = client(server.local_addr);

    // A lone read can never fill a 32-slot block: only the deadline can
    // answer it.
    let t0 = Instant::now();
    let resp = roundtrip(
        &mut reader,
        &mut writer,
        &Request::Score { model: "m".into(), x: joint(6.0, 0.0, 1) },
    );
    let elapsed = t0.elapsed();
    match resp {
        Response::Density { density } => assert!(density.is_finite()),
        other => panic!("unexpected {other:?}"),
    }
    assert!(
        elapsed < Duration::from_secs(5),
        "lone read waited past any plausible deadline: {elapsed:?}"
    );
    let m = registry.metrics().snapshot();
    assert!(m.coalesced_batches >= 1, "read bypassed the coalescer");
    assert!(m.coalesced_reads >= 1);
    server.shutdown();
}

#[test]
fn full_block_flushes_immediately() {
    let registry = trained_registry();
    // Deadline far beyond the test timeout: only the size trigger can
    // answer a full block in time.
    let cfg = ServerConfig {
        drivers: 1,
        batch: BatcherConfig { max_batch: 8, max_delay: Duration::from_secs(10) },
        ..ServerConfig::default()
    };
    let server = serve(registry, cfg).unwrap();
    let (mut reader, mut writer) = client(server.local_addr);

    let mut pipelined = String::new();
    for i in 0..8 {
        let req = Request::Score { model: "m".into(), x: joint(i as f64, 0.0, i % 2) };
        pipelined.push_str(&req.to_json().to_string_compact());
        pipelined.push('\n');
    }
    let t0 = Instant::now();
    writer.write_all(pipelined.as_bytes()).unwrap();
    for _ in 0..8 {
        match read_response(&mut reader) {
            Response::Density { density } => assert!(density.is_finite()),
            other => panic!("unexpected {other:?}"),
        }
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "full block waited for the deadline: {:?}",
        t0.elapsed()
    );
    server.shutdown();
}

#[test]
fn pipelined_mixed_ops_preserve_order() {
    let registry = trained_registry();
    // Deadline 10 s: the scores below can only be answered promptly if
    // the non-coalescable ops barrier-flush the batcher — and the
    // responses must come back in request order.
    let cfg = ServerConfig {
        drivers: 1,
        batch: BatcherConfig { max_batch: 32, max_delay: Duration::from_secs(10) },
        ..ServerConfig::default()
    };
    let server = serve(registry, cfg).unwrap();
    let (mut reader, mut writer) = client(server.local_addr);

    let reqs = vec![
        Request::Score { model: "m".into(), x: joint(6.0, 0.0, 1) },
        Request::Ping,
        Request::Score { model: "m".into(), x: joint(0.0, 0.0, 0) },
        Request::PredictSnapshot { model: "m".into(), features: vec![6.0, 0.0] },
        Request::Stats { model: "m".into() },
        Request::Ping,
    ];
    let mut pipelined = String::new();
    for r in &reqs {
        pipelined.push_str(&r.to_json().to_string_compact());
        pipelined.push('\n');
    }
    let t0 = Instant::now();
    writer.write_all(pipelined.as_bytes()).unwrap();
    let got: Vec<Response> = (0..reqs.len()).map(|_| read_response(&mut reader)).collect();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "barrier flush missing: {:?}",
        t0.elapsed()
    );
    assert!(matches!(got[0], Response::Density { .. }), "{:?}", got[0]);
    assert!(matches!(got[1], Response::Pong), "{:?}", got[1]);
    assert!(matches!(got[2], Response::Density { .. }), "{:?}", got[2]);
    assert!(matches!(got[3], Response::Scores { .. }), "{:?}", got[3]);
    assert!(matches!(got[4], Response::Stats(_)), "{:?}", got[4]);
    assert!(matches!(got[5], Response::Pong), "{:?}", got[5]);
    server.shutdown();
}

/// The tentpole contract: responses served through the coalescing event
/// loop are **byte-identical** to sequential per-request dispatch — in
/// both kernel modes and both search modes, under concurrent clients.
#[test]
fn coalesced_responses_bitwise_equal_per_request() {
    let combos = [
        (KernelMode::Strict, SearchMode::Strict),
        (KernelMode::Strict, SearchMode::TopC { c: 4 }),
        (KernelMode::Fast, SearchMode::Strict),
        (KernelMode::Fast, SearchMode::TopC { c: 4 }),
    ];
    for (kernel, search) in combos {
        let registry = trained_registry_with("m", 6, kernel, search);
        let cfg = ServerConfig {
            batch: BatcherConfig { max_batch: 32, max_delay: Duration::from_millis(2) },
            ..ServerConfig::default()
        };
        let server = serve(registry.clone(), cfg).unwrap();
        let n_sent = hammer_and_compare(&registry, &server, 8, 24);
        server.shutdown();
        let m = registry.metrics().snapshot();
        assert_eq!(
            m.coalesced_reads, n_sent as u64,
            "every single-query read must route through the coalescer \
             (kernel {kernel:?}, search {search:?})"
        );
        assert!(m.coalesced_batches >= 1);
        assert!(m.read_latency.count >= n_sent as u64, "histogram missed reads");

        // Same traffic with coalescing disabled: the per-request event
        // loop must satisfy the identical bitwise contract.
        let registry = trained_registry_with("m", 6, kernel, search);
        let cfg = ServerConfig { coalesce: false, ..ServerConfig::default() };
        let server = serve(registry.clone(), cfg).unwrap();
        hammer_and_compare(&registry, &server, 2, 12);
        server.shutdown();
        assert_eq!(registry.metrics().snapshot().coalesced_reads, 0);
    }
}

/// Fire `threads × per_thread` mixed single-query reads at the server
/// and assert every raw response line equals the sequential
/// `dispatch()` serialization byte for byte. Returns how many requests
/// were sent.
fn hammer_and_compare(
    registry: &Arc<Registry>,
    server: &Server,
    threads: usize,
    per_thread: usize,
) -> usize {
    let addr = server.local_addr;
    let mut handles = Vec::new();
    for t in 0..threads {
        let registry = registry.clone();
        handles.push(std::thread::spawn(move || {
            let (mut reader, mut writer) = client(addr);
            for i in 0..per_thread {
                let req = if i % 2 == 0 {
                    let mut x = vec![(t % 2) as f64 * 6.0, 0.25 * i as f64];
                    x.resize(6, -0.5);
                    x.extend_from_slice(&[0.0, 1.0]); // one-hot class 1
                    Request::Score { model: "m".into(), x }
                } else {
                    let mut f = vec![(i % 2) as f64 * 6.0, -0.25 * t as f64];
                    f.resize(6, 0.5);
                    Request::PredictSnapshot { model: "m".into(), features: f }
                };
                let mut line = req.to_json().to_string_compact();
                line.push('\n');
                writer.write_all(line.as_bytes()).unwrap();
                let mut raw = String::new();
                reader.read_line(&mut raw).unwrap();
                let expect =
                    dispatch(req.clone(), &registry, &None).to_json().to_string_compact();
                assert_eq!(
                    raw.trim_end_matches('\n'),
                    expect,
                    "coalesced response diverged from sequential dispatch for {req:?}"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    threads * per_thread
}
