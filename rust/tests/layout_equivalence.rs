//! Dense-vs-packed layout equivalence — the refactor's acceptance gate.
//!
//! `Figmn` now keeps all component state in flat packed-symmetric
//! arenas (`gmm::ComponentStore`). The packed kernels are specified to
//! perform the **same floating-point operations in the same order** as
//! the dense formulation, so every trajectory must be *bit-identical*
//! to the pre-refactor array-of-structs path. This test replays that
//! pre-refactor path: `DenseRef` below is a faithful reimplementation
//! of the old serial `Figmn` (per-component `mean: Vec<f64>` + dense
//! `Matrix` Λ, dense `quad_form_with`, dense `figmn_fused_update`,
//! `retain`-style prune), built exclusively from the crate's public
//! dense primitives — and the store-backed `Figmn` must match it bit
//! for bit on learn outcomes, component state, densities, posteriors
//! and predictions, for the serial path and thread counts {1, 2, 4},
//! and through `ModelSnapshot` scoring.

use figmn::engine::{logsumexp_tree, tree_sum, EngineConfig};
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture, LearnOutcome};
use figmn::linalg::rank_one::figmn_fused_update;
use figmn::linalg::{dot, sub_into, Cholesky, Matrix};
use figmn::rng::Pcg64;

// ---- pre-refactor dense reference -----------------------------------

struct DenseComp {
    mean: Vec<f64>,
    lambda: Matrix,
    log_det: f64,
    sp: f64,
    v: u64,
}

struct DenseRef {
    cfg: GmmConfig,
    sigma_ini: Vec<f64>,
    comps: Vec<DenseComp>,
}

fn log_gaussian(d2: f64, log_det: f64, dim: usize) -> f64 {
    -0.5 * (dim as f64) * (2.0 * std::f64::consts::PI).ln() - 0.5 * log_det - 0.5 * d2
}

/// Replica of the crate's `softmax_posteriors` (same ops, same order,
/// same deterministic `tree_sum` normalizer).
fn softmax_ref(log_liks: &[f64], sps: &[f64]) -> Vec<f64> {
    let mut best = f64::NEG_INFINITY;
    let mut scores = Vec::with_capacity(log_liks.len());
    for (&ll, &sp) in log_liks.iter().zip(sps.iter()) {
        let s = ll + sp.max(1e-300).ln();
        scores.push(s);
        if s > best {
            best = s;
        }
    }
    if !best.is_finite() {
        let k = log_liks.len().max(1);
        return vec![1.0 / k as f64; log_liks.len()];
    }
    for s in &mut scores {
        *s = (*s - best).exp();
    }
    let total = tree_sum(&scores);
    for s in &mut scores {
        *s /= total;
    }
    scores
}

impl DenseRef {
    fn new(cfg: GmmConfig, stds: &[f64]) -> DenseRef {
        let sigma_ini = cfg.sigma_ini(stds);
        DenseRef { cfg, sigma_ini, comps: Vec::new() }
    }

    fn create(&mut self, x: &[f64]) {
        let d = self.cfg.dim;
        let mut lambda = Matrix::zeros(d, d);
        let mut log_det = 0.0;
        for i in 0..d {
            let s2 = self.sigma_ini[i] * self.sigma_ini[i];
            lambda[(i, i)] = 1.0 / s2;
            log_det += s2.ln();
        }
        self.comps.push(DenseComp { mean: x.to_vec(), lambda, log_det, sp: 1.0, v: 1 });
    }

    fn prune(&mut self) {
        if !self.cfg.prune || self.comps.len() <= 1 {
            return;
        }
        let (v_min, sp_min) = (self.cfg.v_min, self.cfg.sp_min);
        let doomed = |c: &DenseComp| c.v > v_min && c.sp < sp_min;
        if self.comps.iter().all(doomed) {
            let mut keep = 0usize;
            let mut best = self.comps[0].sp;
            for (j, c) in self.comps.iter().enumerate().skip(1) {
                if c.sp > best {
                    best = c.sp;
                    keep = j;
                }
            }
            self.comps.swap(0, keep);
            self.comps.truncate(1);
        } else {
            self.comps.retain(|c| !doomed(c));
        }
    }

    fn learn(&mut self, x: &[f64]) -> LearnOutcome {
        if self.comps.is_empty() {
            self.create(x);
            return LearnOutcome::Created;
        }
        let k = self.comps.len();
        let d = self.cfg.dim;
        let mut d2 = vec![0.0; k];
        let mut ws = vec![0.0; k * d];
        let mut e = vec![0.0; d];
        for (j, c) in self.comps.iter().enumerate() {
            sub_into(x, &c.mean, &mut e);
            d2[j] = c.lambda.quad_form_with(&e, &mut ws[j * d..(j + 1) * d]);
        }
        let accept = d2.iter().any(|&v| v < self.cfg.chi2_threshold());
        let cap_full = self.cfg.max_components > 0 && k >= self.cfg.max_components;
        if accept || cap_full {
            let mut ll = Vec::with_capacity(k);
            let mut sps = Vec::with_capacity(k);
            for (c, &d2j) in self.comps.iter().zip(d2.iter()) {
                ll.push(log_gaussian(d2j, c.log_det, d));
                sps.push(c.sp);
            }
            let post = softmax_ref(&ll, &sps);
            for (j, c) in self.comps.iter_mut().enumerate() {
                c.v += 1;
                c.sp += post[j];
                let omega = post[j] / c.sp;
                if omega <= 0.0 {
                    continue;
                }
                sub_into(x, &c.mean, &mut e);
                for (m, &ei) in c.mean.iter_mut().zip(e.iter()) {
                    *m += omega * ei;
                }
                match figmn_fused_update(
                    &mut c.lambda,
                    &ws[j * d..(j + 1) * d],
                    d2[j],
                    omega,
                    c.log_det,
                ) {
                    Some(r) => c.log_det = r.log_det,
                    None => {
                        c.lambda.scale_in_place(0.0);
                        let mut ld = 0.0;
                        for i in 0..d {
                            let s2 = self.sigma_ini[i] * self.sigma_ini[i];
                            c.lambda[(i, i)] = 1.0 / s2;
                            ld += s2.ln();
                        }
                        c.log_det = ld;
                    }
                }
            }
            self.prune();
            LearnOutcome::Updated
        } else {
            self.create(x);
            self.prune();
            LearnOutcome::Created
        }
    }

    fn log_density(&self, x: &[f64]) -> f64 {
        let d = self.cfg.dim;
        let total_sp: f64 = self.comps.iter().map(|c| c.sp).sum();
        let mut e = vec![0.0; d];
        let mut terms = Vec::with_capacity(self.comps.len());
        for c in &self.comps {
            sub_into(x, &c.mean, &mut e);
            let ll = log_gaussian(c.lambda.quad_form(&e), c.log_det, d);
            terms.push(ll + (c.sp / total_sp).ln());
        }
        logsumexp_tree(&terms)
    }

    fn posteriors(&self, x: &[f64]) -> Vec<f64> {
        let d = self.cfg.dim;
        let mut e = vec![0.0; d];
        let mut ll = Vec::with_capacity(self.comps.len());
        let mut sps = Vec::with_capacity(self.comps.len());
        for c in &self.comps {
            sub_into(x, &c.mean, &mut e);
            ll.push(log_gaussian(c.lambda.quad_form(&e), c.log_det, d));
            sps.push(c.sp);
        }
        softmax_ref(&ll, &sps)
    }

    /// Pre-refactor dense `precision_conditional` (Eq. 27 + Schur
    /// marginal) reading the dense Λ directly.
    fn conditional(
        c: &DenseComp,
        known_vals: &[f64],
        known_idx: &[usize],
        target_idx: &[usize],
    ) -> (f64, Vec<f64>) {
        let ni = known_idx.len();
        let nt = target_idx.len();
        let mut d = vec![0.0; ni];
        for (k, (&idx, &v)) in known_idx.iter().zip(known_vals.iter()).enumerate() {
            d[k] = v - c.mean[idx];
        }
        let mut ytd = vec![0.0; nt];
        for (r, &ti) in target_idx.iter().enumerate() {
            let mut acc = 0.0;
            for (k, &ki) in known_idx.iter().enumerate() {
                acc += c.lambda[(ki, ti)] * d[k];
            }
            ytd[r] = acc;
        }
        let mut dxd = 0.0;
        for (a, &ia) in known_idx.iter().enumerate() {
            let mut acc = 0.0;
            for (b, &ib) in known_idx.iter().enumerate() {
                acc += c.lambda[(ia, ib)] * d[b];
            }
            dxd += d[a] * acc;
        }
        let mut w = Matrix::zeros(nt, nt);
        for (a, &ta) in target_idx.iter().enumerate() {
            for (b, &tb) in target_idx.iter().enumerate() {
                w[(a, b)] = c.lambda[(ta, tb)];
            }
        }
        let chol = Cholesky::new(&w).expect("W must be PD");
        let z = chol.solve(&ytd);
        let mut recon = vec![0.0; nt];
        for (r, &ti) in target_idx.iter().enumerate() {
            recon[r] = c.mean[ti] - z[r];
        }
        let d2 = dxd - dot(&ytd, &z);
        let log_det_a = c.log_det + chol.log_det();
        (log_gaussian(d2.max(0.0), log_det_a, ni), recon)
    }

    fn predict(&self, known_vals: &[f64], known_idx: &[usize], target_idx: &[usize]) -> Vec<f64> {
        let mut log_liks = Vec::with_capacity(self.comps.len());
        let mut recons = Vec::with_capacity(self.comps.len());
        let mut sps = Vec::with_capacity(self.comps.len());
        for c in &self.comps {
            let (ll, rc) = DenseRef::conditional(c, known_vals, known_idx, target_idx);
            log_liks.push(ll);
            recons.push(rc);
            sps.push(c.sp);
        }
        let post = softmax_ref(&log_liks, &sps);
        let mut out = vec![0.0; target_idx.len()];
        for (p, r) in post.iter().zip(recons.iter()) {
            for (o, &v) in out.iter_mut().zip(r.iter()) {
                *o += p * v;
            }
        }
        out
    }
}

// ---- assertions ------------------------------------------------------

fn assert_matches_dense(dense: &DenseRef, m: &Figmn, probes: &[Vec<f64>], tag: &str) {
    assert_eq!(dense.comps.len(), m.num_components(), "{tag}: K");
    for (j, c) in dense.comps.iter().enumerate() {
        assert_eq!(c.mean.as_slice(), m.component_mean(j), "{tag}: mean[{j}]");
        assert_eq!(
            c.lambda.as_slice(),
            m.component_lambda(j).as_slice(),
            "{tag}: lambda[{j}]"
        );
        assert!(
            c.log_det.to_bits() == m.component_log_det(j).to_bits(),
            "{tag}: log_det[{j}] {} vs {}",
            c.log_det,
            m.component_log_det(j)
        );
        let (sp, v) = m.component_stats(j);
        assert!(c.sp.to_bits() == sp.to_bits(), "{tag}: sp[{j}]");
        assert_eq!(c.v, v, "{tag}: v[{j}]");
    }
    let d = m.dim();
    let known: Vec<usize> = (0..d - 1).collect();
    let snap = m.snapshot();
    for (i, x) in probes.iter().enumerate() {
        assert!(
            dense.log_density(x).to_bits() == m.log_density(x).to_bits(),
            "{tag}: log_density[{i}]"
        );
        assert_eq!(dense.posteriors(x), m.posteriors(x), "{tag}: posteriors[{i}]");
        assert_eq!(
            dense.predict(&x[..d - 1], &known, &[d - 1]),
            m.predict(&x[..d - 1], &known, &[d - 1]),
            "{tag}: predict[{i}]"
        );
        // The arena-copied snapshot scores bit-identically too.
        assert!(
            snap.log_density(x).to_bits() == dense.log_density(x).to_bits(),
            "{tag}: snapshot log_density[{i}]"
        );
        assert_eq!(snap.posteriors(x), dense.posteriors(x), "{tag}: snapshot posteriors[{i}]");
    }
}

fn cluster_stream(d: usize, n_clusters: usize, n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    let centers: Vec<Vec<f64>> =
        (0..n_clusters).map(|_| (0..d).map(|_| rng.normal() * 12.0).collect()).collect();
    (0..n)
        .map(|i| centers[i % n_clusters].iter().map(|&c| c + rng.normal() * 0.7).collect())
        .collect()
}

// ---- the property tests ---------------------------------------------

/// Serial + thread counts {1, 2, 4}: the store-backed model replays the
/// dense reference bit for bit on multi-cluster streams.
#[test]
fn packed_store_matches_dense_reference_bitwise() {
    for (seed, d) in [(1u64, 3usize), (2, 5), (3, 7)] {
        let cfg = GmmConfig::new(d).with_delta(0.4).with_beta(0.1).without_pruning();
        let stds = vec![2.0; d];
        let stream = cluster_stream(d, 3, 150, seed);
        let probes = cluster_stream(d, 3, 8, seed + 100);

        let mut dense = DenseRef::new(cfg.clone(), &stds);
        let mut serial = Figmn::new(cfg.clone(), &stds);
        let mut pooled: Vec<Figmn> = [1usize, 2, 4]
            .iter()
            .map(|&t| Figmn::new(cfg.clone(), &stds).with_engine(EngineConfig::new(t)))
            .collect();
        for (step, x) in stream.iter().enumerate() {
            let want = dense.learn(x);
            assert_eq!(want, serial.learn(x), "seed {seed}: outcome diverged at step {step}");
            for m in pooled.iter_mut() {
                assert_eq!(want, m.learn(x), "seed {seed}: pooled outcome at step {step}");
            }
        }
        assert!(dense.comps.len() >= 2, "seed {seed}: stream too tame");
        assert_matches_dense(&dense, &serial, &probes, &format!("seed {seed} serial"));
        for (m, t) in pooled.iter().zip([1usize, 2, 4]) {
            assert_matches_dense(&dense, m, &probes, &format!("seed {seed} T={t}"));
        }
    }
}

/// A high-K wide stream that crosses the engine's parallel-work gate
/// (K·D² ≫ 2¹⁴), so the sharded arenas demonstrably run — and still
/// replay the dense reference bit for bit.
#[test]
fn packed_store_matches_dense_reference_high_k() {
    let d = 24;
    let k_cap = 64;
    let cfg = GmmConfig::new(d)
        .with_delta(1.0)
        .with_beta(0.05)
        .with_max_components(k_cap)
        .without_pruning();
    let stds = vec![1.0; d];
    let stream = cluster_stream(d, k_cap, 500, 17);
    let probes: Vec<Vec<f64>> = stream[..6].to_vec();

    let mut dense = DenseRef::new(cfg.clone(), &stds);
    for x in &stream {
        dense.learn(x);
    }
    assert_eq!(dense.comps.len(), k_cap, "gate never crossed");
    for t in [1usize, 2, 4] {
        let mut m = Figmn::new(cfg.clone(), &stds).with_engine(EngineConfig::new(t));
        m.learn_batch(&stream);
        assert_matches_dense(&dense, &m, &probes, &format!("high-K T={t}"));
    }
}

/// The prune path (stable compaction + keep-strongest) is also
/// layout-invariant: trajectories with aggressive pruning stay
/// bit-identical, including component order after removals.
#[test]
fn packed_store_matches_dense_reference_with_pruning() {
    for seed in [5u64, 6, 7] {
        let d = 3;
        let cfg = GmmConfig::new(d).with_delta(0.3).with_beta(0.2).with_pruning(3, 2.0);
        let stds = vec![2.0; d];
        let mut rng = Pcg64::seed(seed);
        let mut dense = DenseRef::new(cfg.clone(), &stds);
        let mut m = Figmn::new(cfg, &stds);
        for step in 0..200 {
            // Clustered points with periodic far outliers so spurious
            // components appear and the prune sweep actually fires.
            let x: Vec<f64> = if step % 9 == 8 {
                (0..d).map(|_| rng.normal() * 50.0).collect()
            } else {
                (0..d).map(|i| (step % 2 * 10) as f64 + i as f64 + rng.normal() * 0.5).collect()
            };
            assert_eq!(dense.learn(&x), m.learn(&x), "seed {seed}: outcome at step {step}");
            assert_eq!(
                dense.comps.len(),
                m.num_components(),
                "seed {seed}: prune diverged at step {step}"
            );
        }
        let probes = cluster_stream(d, 2, 6, seed + 50);
        assert_matches_dense(&dense, &m, &probes, &format!("prune seed {seed}"));
    }
}
