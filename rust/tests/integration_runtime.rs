//! Cross-layer integration: the Rust-native f64 FIGMN and the AOT XLA
//! artifacts (f32, Pallas-kernel-backed) must agree on the same stream —
//! learn decisions, posteriors, and conditional predictions.
//!
//! Requires `make artifacts`; tests skip (with a note) when the artifact
//! directory is absent so `cargo test` stays green pre-build.

use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture, LearnOutcome};
use figmn::rng::Pcg64;
use figmn::runtime::{PackedState, Runtime};

const CONFIG: &str = "blobs3";
const DIM: usize = 5; // 2 features + 3 one-hot classes
const CAPACITY: usize = 16;
const BATCH: usize = 32;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping integration test: run `make artifacts` first");
        return None;
    }
    Some(Runtime::open(dir).expect("artifact dir must open"))
}

/// Well-separated 3-class blobs in 2-D, one-hot encoded into 5-D joints.
fn joint_stream(n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    let centers = [[0.0, 0.0], [8.0, 8.0], [0.0, 8.0]];
    (0..n)
        .map(|i| {
            let c = i % 3;
            let mut x = vec![
                centers[c][0] + rng.normal() * 0.5,
                centers[c][1] + rng.normal() * 0.5,
            ];
            for k in 0..3 {
                x.push(if k == c { 1.0 } else { 0.0 });
            }
            x
        })
        .collect()
}

fn cfg() -> GmmConfig {
    GmmConfig::new(DIM).with_delta(0.6).with_beta(0.05).without_pruning()
}

fn stds() -> Vec<f64> {
    vec![4.0, 4.0, 0.5, 0.5, 0.5]
}

#[test]
fn learn_path_matches_native() {
    let Some(rt) = runtime() else { return };
    let learn = rt.learn_exec(CONFIG).expect("learn artifact");
    assert_eq!(learn.meta().dim, DIM);
    assert_eq!(learn.meta().capacity, CAPACITY);

    let config = cfg();
    let chi2 = config.chi2_threshold() as f32;
    let sigma: Vec<f32> = config.sigma_ini(&stds()).iter().map(|&v| v as f32).collect();

    let mut native = Figmn::new(config, &stds());
    let mut state = PackedState::empty(CAPACITY, DIM);

    for (step, x) in joint_stream(90, 7).iter().enumerate() {
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let out = learn.learn(&xf, &state, chi2, &sigma).expect("learn step");
        let outcome = native.learn(x);
        assert_eq!(
            out.updated,
            outcome == LearnOutcome::Updated,
            "create/update decision diverged at step {step}"
        );
        state = out.state;
        assert_eq!(state.active(), native.num_components(), "K diverged at step {step}");
    }

    // Component means agree to f32 tolerance.
    for j in 0..native.num_components() {
        let mean = native.component_mean(j);
        for (i, &v) in mean.iter().enumerate() {
            let got = state.mus[j * DIM + i] as f64;
            assert!(
                (got - v).abs() < 1e-3 * (1.0 + v.abs()),
                "mean[{j}][{i}]: xla {got} vs native {v}"
            );
        }
        // log-dets agree.
        let ld = native.component_log_det(j);
        let got_ld = state.log_dets[j] as f64;
        assert!((got_ld - ld).abs() < 2e-2 * (1.0 + ld.abs()), "log_det[{j}]: {got_ld} vs {ld}");
    }
}

#[test]
fn score_path_matches_native_posteriors() {
    let Some(rt) = runtime() else { return };
    let learn = rt.learn_exec(CONFIG).unwrap();
    let score = rt.score_exec(CONFIG).unwrap();

    let config = cfg();
    let chi2 = config.chi2_threshold() as f32;
    let sigma: Vec<f32> = config.sigma_ini(&stds()).iter().map(|&v| v as f32).collect();
    let mut native = Figmn::new(config, &stds());
    let mut state = PackedState::empty(CAPACITY, DIM);
    for x in joint_stream(60, 11) {
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        state = learn.learn(&xf, &state, chi2, &sigma).unwrap().state;
        native.learn(&x);
    }

    let queries = joint_stream(BATCH, 13);
    let mut xs = Vec::with_capacity(BATCH * DIM);
    for q in &queries {
        xs.extend(q.iter().map(|&v| v as f32));
    }
    let out = score.score(&xs, &state).expect("score");
    assert_eq!(out.posteriors.len(), BATCH * CAPACITY);

    for (b, q) in queries.iter().enumerate() {
        let native_post = native.posteriors(q);
        let row = &out.posteriors[b * CAPACITY..(b + 1) * CAPACITY];
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "row {b} not normalized: {sum}");
        for (j, &np) in native_post.iter().enumerate() {
            assert!(
                (row[j] as f64 - np).abs() < 5e-3,
                "posterior[{b}][{j}]: xla {} vs native {np}",
                row[j]
            );
        }
        // Masked slots stay zero.
        for j in native_post.len()..CAPACITY {
            assert_eq!(row[j], 0.0);
        }
    }
}

#[test]
fn predict_path_matches_native() {
    let Some(rt) = runtime() else { return };
    let learn = rt.learn_exec(CONFIG).unwrap();
    let predict = rt.predict_exec(CONFIG).unwrap();
    assert_eq!(predict.meta().n_known, 2);

    let config = cfg();
    let chi2 = config.chi2_threshold() as f32;
    let sigma: Vec<f32> = config.sigma_ini(&stds()).iter().map(|&v| v as f32).collect();
    let mut native = Figmn::new(config, &stds());
    let mut state = PackedState::empty(CAPACITY, DIM);
    for x in joint_stream(90, 17) {
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        state = learn.learn(&xf, &state, chi2, &sigma).unwrap().state;
        native.learn(&x);
    }

    let queries = joint_stream(BATCH, 19);
    let mut xs_known = Vec::with_capacity(BATCH * 2);
    for q in &queries {
        xs_known.push(q[0] as f32);
        xs_known.push(q[1] as f32);
    }
    let recon = predict.predict(&xs_known, &state).expect("predict");
    assert_eq!(recon.len(), BATCH * 3);

    for (b, q) in queries.iter().enumerate() {
        let native_recon = native.predict(&q[..2], &[0, 1], &[2, 3, 4]);
        for (o, &nv) in native_recon.iter().enumerate() {
            let got = recon[b * 3 + o] as f64;
            assert!(
                (got - nv).abs() < 5e-3 * (1.0 + nv.abs()),
                "recon[{b}][{o}]: xla {got} vs native {nv}"
            );
        }
        // The reconstructed one-hot block should argmax to the true class.
        let true_class = (0..3).max_by(|&a, &b| q[2 + a].partial_cmp(&q[2 + b]).unwrap()).unwrap();
        let got_class = (0..3usize)
            .max_by(|&i, &j| recon[b * 3 + i].partial_cmp(&recon[b * 3 + j]).unwrap())
            .unwrap();
        assert_eq!(got_class, true_class, "class mismatch at row {b}");
    }
}
