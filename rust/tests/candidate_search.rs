//! Candidate-index search (`SearchMode::TopC`) property tests, through
//! the public API only:
//!
//!   - the accept/create **decision** always matches the full-K sweep,
//!     including streams where only the exact-fallback gate can find
//!     the accepting component (top-C candidates are ranked by
//!     Euclidean mean distance; acceptance is Mahalanobis),
//!   - `c ≥ K` reproduces the strict path bit for bit,
//!   - TopC results are bit-identical across worker thread counts,
//!     with the index surviving create + prune churn,
//!   - top-C recall on clustered streams.

use figmn::engine::EngineConfig;
use figmn::gmm::{Figmn, GmmConfig, IncrementalMixture, LearnOutcome, SearchMode};
use figmn::rng::Pcg64;

/// Bitwise arena comparison. `include_v`: the update-count bookkeeping
/// `v` only advances for evaluated components under TopC (it feeds
/// nothing but pruning), so strict-vs-TopC comparisons on separated
/// data exclude it while same-mode thread comparisons include it.
fn assert_models_match(a: &Figmn, b: &Figmn, include_v: bool, tag: &str) {
    assert_eq!(a.num_components(), b.num_components(), "{tag}: K diverged");
    for j in 0..a.num_components() {
        assert_eq!(a.component_mean(j), b.component_mean(j), "{tag}: mean[{j}]");
        assert_eq!(
            a.component_lambda(j).as_slice(),
            b.component_lambda(j).as_slice(),
            "{tag}: lambda[{j}]"
        );
        assert!(a.component_log_det(j) == b.component_log_det(j), "{tag}: log_det[{j}]");
        let (sp_a, v_a) = a.component_stats(j);
        let (sp_b, v_b) = b.component_stats(j);
        assert!(sp_a == sp_b, "{tag}: sp[{j}]");
        if include_v {
            assert_eq!(v_a, v_b, "{tag}: v[{j}]");
        }
    }
}

fn clustered_stream(d: usize, n_clusters: usize, reps: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed(seed);
    let centers: Vec<Vec<f64>> = (0..n_clusters)
        .map(|_| (0..d).map(|_| rng.normal() * 50.0).collect())
        .collect();
    let mut out: Vec<Vec<f64>> = centers.clone();
    for _ in 0..reps {
        for c in &centers {
            out.push(c.iter().map(|&v| v + rng.normal() * 0.3).collect());
        }
    }
    out
}

/// On well-separated clusters every non-candidate posterior underflows
/// below the arenas' representable contribution, so TopC must track the
/// strict model **bitwise** (except `v`) while genuinely restricting
/// its sweeps to C ≪ K components.
#[test]
fn topc_tracks_strict_bitwise_on_separated_clusters() {
    assert_eq!(SearchMode::default(), SearchMode::Strict);
    let d = 8;
    let stream = clustered_stream(d, 24, 8, 5);
    for c in [2usize, 4] {
        // β = 0.005: the χ² update region comfortably covers the 0.3σ
        // in-cluster noise, so exactly one component per cluster.
        let base = GmmConfig::new(d).with_delta(1.0).with_beta(0.005).without_pruning();
        let mut strict = Figmn::new(base.clone(), &vec![1.0; d]);
        let mut topc = Figmn::new(
            base.with_search_mode(SearchMode::TopC { c }),
            &vec![1.0; d],
        );
        for (i, x) in stream.iter().enumerate() {
            let (a, b) = (strict.learn(x), topc.learn(x));
            assert_eq!(a, b, "c={c}: outcome diverged at step {i}");
        }
        assert_eq!(strict.num_components(), 24, "c={c}: cluster count");
        assert_models_match(&strict, &topc, false, &format!("c={c}"));
        // Scores on near-cluster probes agree to tolerance (the dropped
        // tail is below double-precision resolution here).
        for x in stream.iter().rev().take(48) {
            let (ls, lt) = (strict.log_density(x), topc.log_density(x));
            let rel = (ls - lt).abs() / ls.abs().max(1.0);
            assert!(rel < 1e-9, "log_density drifted: {ls} vs {lt}");
        }
    }
}

/// The exact-fallback gate: candidates are ranked by Euclidean mean
/// distance, so a tight component can shadow a wide one whose χ² region
/// actually contains the point. The gate must find the wide component
/// and update — without it, TopC would create where full-K updates.
#[test]
fn fallback_gate_matches_full_k_where_euclidean_ranking_misleads() {
    let d = 2;
    let base = GmmConfig::new(d).with_delta(1.0).with_beta(0.05).without_pruning();
    let mut strict = Figmn::new(base.clone(), &vec![1.0; d]);
    let mut topc = Figmn::new(
        base.with_search_mode(SearchMode::TopC { c: 1 }),
        &vec![1.0; d],
    );

    // Component A at (0, 2), trained tight: its χ² region shrinks far
    // below its Euclidean footprint.
    let mut stream: Vec<Vec<f64>> = vec![vec![0.0, 2.0]];
    let mut rng = Pcg64::seed(17);
    for _ in 0..20 {
        stream.push(vec![rng.normal() * 0.05, 2.0 + rng.normal() * 0.05]);
    }
    // Component B at (0, -6), trained with a widening spread along
    // dim 1 (each stage stays inside the current χ² region, so no
    // stage creates): B ends up reaching most of the way toward A.
    stream.push(vec![0.0, -6.0]);
    for &u in &[1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 4.5] {
        for _ in 0..2 {
            stream.push(vec![0.0, -6.0 + u]);
            stream.push(vec![0.0, -6.0 - u]);
        }
    }
    for (i, x) in stream.iter().enumerate() {
        let (a, b) = (strict.learn(x), topc.learn(x));
        assert_eq!(a, b, "outcome diverged at step {i}");
    }
    // The construction must have produced exactly the two components.
    assert_eq!(strict.num_components(), 2, "construction drifted");
    assert_eq!(topc.num_components(), 2, "construction drifted (topc)");

    // The probe: Euclidean-nearest mean is A (3.0 vs 5.0 away), but
    // only B's χ² region contains it. With c = 1 the candidate set is
    // {A}; the fallback gate must surface B in both the decision and
    // the update, exactly as the full sweep does.
    let probe = vec![0.0, -1.0];
    let (a, b) = (strict.learn(&probe), topc.learn(&probe));
    assert_eq!(a, LearnOutcome::Updated, "construction drifted: full-K created");
    assert_eq!(b, LearnOutcome::Updated, "fallback gate missed the accepting component");
    assert_models_match(&strict, &topc, false, "post-probe");
}

/// `c ≥ K`: the candidate set is all of `0..K` in ascending order —
/// the same arithmetic in the same order as the strict sweep, so
/// outcomes, arenas (including `v`), and scores match bit for bit even
/// on heavily overlapping streams.
#[test]
fn full_c_is_bitwise_identical_to_strict_on_overlapping_stream() {
    let d = 4;
    let mut rng = Pcg64::seed(23);
    // Overlapping clusters: posterior mass genuinely spreads across
    // components, so this exercises the shared-order reductions.
    let stream: Vec<Vec<f64>> = (0..400)
        .map(|i| {
            let c = (i % 5) as f64 * 2.0;
            (0..d).map(|_| c + rng.normal()).collect()
        })
        .collect();
    let base = GmmConfig::new(d).with_delta(1.0).with_beta(0.1).without_pruning();
    let mut strict = Figmn::new(base.clone(), &vec![1.0; d]);
    let mut topc = Figmn::new(
        base.with_search_mode(SearchMode::TopC { c: 4096 }),
        &vec![1.0; d],
    );
    for (i, x) in stream.iter().enumerate() {
        assert_eq!(strict.learn(x), topc.learn(x), "outcome diverged at step {i}");
    }
    assert_models_match(&strict, &topc, true, "full-c");
    let probes: Vec<Vec<f64>> = stream.iter().rev().take(50).cloned().collect();
    assert!(
        strict.score_batch(&probes) == topc.score_batch(&probes),
        "full-c scores not bitwise identical"
    );
    for x in probes.iter().take(10) {
        assert!(strict.posteriors(x) == topc.posteriors(x), "full-c posteriors diverged");
    }
}

/// TopC determinism across worker thread counts, with pruning on: the
/// index survives create + prune churn (every prune bumps the arena
/// generation and forces a rebuild) and the arenas stay bit-identical
/// at every thread count, `v` included.
#[test]
fn topc_is_thread_invariant_across_create_and_prune() {
    let d = 2;
    let mut rng = Pcg64::seed(31);
    // A strong origin cluster plus three one-shot outliers: the
    // outliers' components age as candidates (v grows, sp stays ~1)
    // until the §2.3 sweep removes them.
    let mut stream: Vec<Vec<f64>> = (0..20)
        .map(|_| vec![rng.normal() * 0.5, rng.normal() * 0.5])
        .collect();
    stream.push(vec![8.0, 8.0]);
    stream.push(vec![-8.0, 8.0]);
    stream.push(vec![8.0, -8.0]);
    for _ in 0..80 {
        stream.push(vec![rng.normal() * 0.5, rng.normal() * 0.5]);
    }

    let build = |threads: usize| {
        // β = 1e-4: the χ² region covers the whole origin cluster, so
        // exactly the three outliers create (asserted below).
        let cfg = GmmConfig::new(d)
            .with_delta(1.0)
            .with_beta(0.0001)
            .with_pruning(5, 3.0)
            .with_search_mode(SearchMode::TopC { c: 3 });
        let mut m = Figmn::new(cfg, &vec![1.0; d]);
        if threads > 1 {
            m.set_engine(Some(EngineConfig::new(threads)));
        }
        let created = stream.iter().filter(|x| m.learn(x) == LearnOutcome::Created).count();
        (m, created)
    };

    let (reference, created) = build(1);
    // The scenario must actually churn: 4 creates, 3 prunes.
    assert_eq!(created, 4, "expected the three outliers to create");
    assert_eq!(reference.num_components(), 1, "expected the outliers to be pruned");
    assert!(reference.log_density(&[0.0, 0.0]).is_finite());
    for threads in [2usize, 4] {
        let (pooled, created_t) = build(threads);
        assert_eq!(created, created_t, "T={threads}: create count diverged");
        assert_models_match(&reference, &pooled, true, &format!("T={threads}"));
    }
}

/// Recall on a clustered stream: for near-cluster probes the strict
/// model's best component must be inside the candidate set TopC
/// renormalizes over (visible as a nonzero TopC posterior).
#[test]
fn topc_recall_on_clustered_probes() {
    let d = 8;
    let c = 4;
    let stream = clustered_stream(d, 30, 10, 41);
    let base = GmmConfig::new(d).with_delta(1.0).with_beta(0.005).without_pruning();
    let mut strict = Figmn::new(base.clone(), &vec![1.0; d]);
    let mut topc = Figmn::new(
        base.with_search_mode(SearchMode::TopC { c }),
        &vec![1.0; d],
    );
    for x in &stream {
        strict.learn(x);
        topc.learn(x);
    }
    assert_eq!(strict.num_components(), 30);
    assert!(strict.num_components() > c, "recall test needs C < K");

    let mut rng = Pcg64::seed(43);
    let probes: Vec<&Vec<f64>> = (0..100).map(|_| &stream[rng.below(stream.len())]).collect();
    let mut hits = 0usize;
    for &x in &probes {
        let ps = strict.posteriors(x);
        let pt = topc.posteriors(x);
        assert_eq!(ps.len(), pt.len(), "posterior shape contract");
        let best = ps
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(j, _)| j)
            .unwrap();
        if pt[best] > 0.0 {
            hits += 1;
        }
    }
    assert!(hits >= 95, "top-C recall {hits}/100 below threshold");
}
