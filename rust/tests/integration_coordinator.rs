//! Coordinator integration: the full control plane — create, stream,
//! shard, checkpoint, restore, drop — through the public API.

use figmn::coordinator::protocol::{Request, Response};
use figmn::coordinator::server::dispatch;
use figmn::coordinator::{
    CheckpointStore, Metrics, ModelSpec, Registry, RoutingPolicy,
};
use figmn::gmm::supervised::supervised_figmn;
use figmn::gmm::{GmmConfig, IncrementalMixture};
use figmn::rng::Pcg64;
use std::sync::Arc;

fn blob(rng: &mut Pcg64, c: usize) -> Vec<f64> {
    let centers = [[0.0, 0.0], [7.0, 7.0], [0.0, 7.0]];
    vec![centers[c][0] + rng.normal() * 0.7, centers[c][1] + rng.normal() * 0.7]
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("figmn-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn checkpoint_restore_cycle() {
    let store = CheckpointStore::new(tmpdir("ckpt")).unwrap();
    let registry = Registry::new(Arc::new(Metrics::new())).with_checkpoints(store.clone());
    registry
        .create(
            ModelSpec::new("m", 2, 3)
                .with_gmm(GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning())
                .with_stds(vec![3.0, 3.0]),
        )
        .unwrap();
    let router = registry.router("m").unwrap();
    let mut rng = Pcg64::seed(1);
    for i in 0..150 {
        router.learn(blob(&mut rng, i % 3), i % 3).unwrap();
    }
    let paths = registry.checkpoint("m").unwrap();
    assert_eq!(paths.len(), 1);

    // Restore the shard model directly from disk and verify it predicts
    // like the live one.
    let restored = store.load("m", 0).unwrap();
    assert!(restored.num_components() >= 3);
    for i in 0..30 {
        let c = i % 3;
        let x = blob(&mut rng, c);
        let live = router.predict(&x).unwrap();
        // joint = [x, one-hot]; restored model is the raw joint mixture.
        let recon = restored.predict(&x, &[0, 1], &[2, 3, 4]);
        let live_best = live.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        let rest_best = recon.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        assert_eq!(live_best, rest_best, "restored model diverged at {i}");
    }
    std::fs::remove_dir_all(store.dir()).unwrap();
}

#[test]
fn dispatch_covers_full_protocol_surface() {
    let registry = Registry::new(Arc::new(Metrics::new()));
    let xla = None;

    assert_eq!(dispatch(Request::Ping, &registry, &xla), Response::Pong);
    let create = Request::CreateModel {
        model: "p".into(),
        n_features: 2,
        n_classes: 2,
        delta: 0.5,
        beta: 0.05,
        stds: vec![2.0, 2.0],
        shards: 2,
        kernel_mode: figmn::gmm::KernelMode::Strict,
        search_mode: figmn::gmm::SearchMode::Strict,
        replica_mode: Some(figmn::gmm::ReplicaMode::f32_default()),
    };
    assert_eq!(dispatch(create.clone(), &registry, &xla), Response::Ok);
    // Duplicate create fails.
    assert!(matches!(dispatch(create, &registry, &xla), Response::Error(_)));

    // Wrong arity / label range rejected.
    let bad_feats =
        Request::Learn { model: "p".into(), features: vec![1.0], label: 0 };
    assert!(matches!(dispatch(bad_feats, &registry, &xla), Response::Error(_)));
    let bad_label =
        Request::Learn { model: "p".into(), features: vec![1.0, 2.0], label: 9 };
    assert!(matches!(dispatch(bad_label, &registry, &xla), Response::Error(_)));

    let mut rng = Pcg64::seed(2);
    for i in 0..100 {
        let c = i % 2;
        let req = Request::Learn {
            model: "p".into(),
            features: vec![c as f64 * 6.0 + rng.normal() * 0.5, rng.normal() * 0.5],
            label: c,
        };
        assert_eq!(dispatch(req, &registry, &xla), Response::Ok);
    }
    match dispatch(
        Request::Predict { model: "p".into(), features: vec![6.0, 0.0] },
        &registry,
        &xla,
    ) {
        Response::Scores { class, scores } => {
            assert_eq!(class, 1);
            assert_eq!(scores.len(), 2);
        }
        other => panic!("unexpected {other:?}"),
    }
    match dispatch(Request::Stats { model: "p".into() }, &registry, &xla) {
        Response::Stats(j) => {
            assert_eq!(j.get("shards").unwrap().as_usize(), Some(2));
            assert_eq!(j.get("learned").unwrap().as_usize(), Some(100));
        }
        other => panic!("unexpected {other:?}"),
    }
    // Checkpointing disabled → clean error.
    assert!(matches!(
        dispatch(Request::Checkpoint { model: "p".into() }, &registry, &xla),
        Response::Error(_)
    ));
    assert_eq!(dispatch(Request::DropModel { model: "p".into() }, &registry, &xla), Response::Ok);
    assert!(matches!(
        dispatch(Request::Stats { model: "p".into() }, &registry, &xla),
        Response::Error(_)
    ));
}

/// The serving read path's core guarantee: scores served from a
/// published snapshot are bit-identical to a serial model trained on
/// the same prefix (no engine, no coordinator).
#[test]
fn snapshot_read_path_is_bit_identical_to_serial_model() {
    let registry = Registry::new(Arc::new(Metrics::new())).with_scorers(2);
    let gmm = GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning();
    registry
        .create(
            ModelSpec::new("m", 2, 3)
                .with_gmm(gmm.clone())
                .with_stds(vec![3.0, 3.0])
                .with_snapshot_interval(4),
        )
        .unwrap();
    let router = registry.router("m").unwrap();
    // Serial twin fed the same stream (supervised_figmn builds the same
    // joint config the worker does).
    let mut serial = supervised_figmn(gmm, &[3.0, 3.0], 3);
    let mut rng = Pcg64::seed(6);
    for i in 0..32 {
        let c = i % 3;
        let x = blob(&mut rng, c);
        router.learn(x.clone(), c).unwrap();
        serial.train_one(&x, c);
    }
    // Drain the queue; 32 is a multiple of the interval, so the last
    // publish already covers the full prefix.
    registry.stats("m").unwrap();
    router.shards()[0]
        .wait_snapshot_points(32, 1000)
        .expect("snapshot never caught up");
    for i in 0..20 {
        let c = i % 3;
        let x = blob(&mut rng, c);
        assert_eq!(
            router.predict_read(&x).unwrap(),
            serial.class_scores(&x),
            "snapshot read diverged from the serial model"
        );
    }
    let snap = router.shards()[0].snapshot().unwrap();
    let joint = vec![7.0, 7.0, 0.0, 1.0, 0.0];
    assert!(snap.log_density(&joint) == serial.model().log_density(&joint));
    assert!(router.score_read(&joint).unwrap() == serial.model().log_density(&joint));
}

#[test]
fn sharded_ensemble_beats_nothing_and_agrees() {
    // Broadcast ensemble over 3 shards must classify the blobs correctly
    // and deterministically.
    let registry = Registry::new(Arc::new(Metrics::new()));
    registry
        .create(
            ModelSpec::new("e", 2, 3)
                .with_gmm(GmmConfig::new(1).with_delta(0.5).with_beta(0.05).without_pruning())
                .with_stds(vec![3.0, 3.0])
                .with_shards(3, RoutingPolicy::Broadcast),
        )
        .unwrap();
    let router = registry.router("e").unwrap();
    let mut rng = Pcg64::seed(3);
    for i in 0..300 {
        router.learn(blob(&mut rng, i % 3), i % 3).unwrap();
    }
    let mut correct = 0;
    for i in 0..60 {
        let c = i % 3;
        let scores = router.predict(&blob(&mut rng, c)).unwrap();
        let best = scores.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        if best == c {
            correct += 1;
        }
    }
    assert!(correct >= 57, "ensemble accuracy {correct}/60");
}

#[test]
fn backpressure_sheds_under_overload() {
    use figmn::coordinator::worker::{Worker, WorkerConfig};
    use figmn::coordinator::OverflowPolicy;

    let metrics = Arc::new(Metrics::new());
    let mut cfg = WorkerConfig::new(
        2,
        2,
        GmmConfig::new(1).with_delta(0.5).with_beta(0.0).without_pruning(),
        vec![1.0, 1.0],
    );
    cfg.queue_capacity = 4;
    cfg.overflow = OverflowPolicy::DropNewest;
    let worker = Worker::spawn(cfg, metrics);

    // Flood far faster than the worker drains; some learns must be shed
    // (Err) rather than ballooning memory.
    let mut shed = 0;
    for i in 0..10_000 {
        if worker.handle.learn(vec![i as f64 * 1e-4, 0.0], 0).is_err() {
            shed += 1;
        }
    }
    // The stats command itself can be shed while the queue is full —
    // retry until the worker drains.
    let stats = loop {
        match worker.handle.stats() {
            Ok(s) => break s,
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(5)),
        }
    };
    assert_eq!(stats.learned + shed as u64, 10_000, "nothing lost silently");
    worker.join();
}
