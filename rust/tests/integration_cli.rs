//! CLI smoke tests — the `figmn` binary end to end via
//! `CARGO_BIN_EXE_figmn`.

use std::process::Command;

fn figmn(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_figmn"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn figmn");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn version_prints() {
    let (stdout, _, ok) = figmn(&["version"]);
    assert!(ok);
    assert!(stdout.contains("figmn 0.1.0"));
}

#[test]
fn datasets_prints_table1() {
    let (stdout, _, ok) = figmn(&["datasets"]);
    assert!(ok);
    for name in ["breast-cancer", "CIFAR-10", "MNIST", "twospirals", "soybean"] {
        assert!(stdout.contains(name), "missing {name} in:\n{stdout}");
    }
    assert!(stdout.contains("3072"));
    assert!(stdout.contains("784"));
}

#[test]
fn train_runs_both_variants() {
    let (stdout, stderr, ok) = figmn(&["train", "iris", "--delta", "1", "--beta", "0.001"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("AUC"), "{stdout}");
    let (stdout2, _, ok2) =
        figmn(&["train", "iris", "--delta", "1", "--beta", "0.001", "--algo", "orig"]);
    assert!(ok2);
    // Both variants report the same AUC (equivalence through the CLI).
    let auc = |s: &str| s.split("AUC ").nth(1).unwrap()[..5].to_string();
    assert_eq!(auc(&stdout), auc(&stdout2));
}

#[test]
fn train_kernel_mode_flag() {
    // Fast kernels are tolerance-equivalent: same discovered structure,
    // same AUC to the printed precision on this easy stream.
    let (strict_out, stderr, ok) =
        figmn(&["train", "iris", "--delta", "1", "--beta", "0.001", "--kernel-mode", "strict"]);
    assert!(ok, "stderr: {stderr}");
    assert!(strict_out.contains("kernels=strict"), "{strict_out}");
    let (fast_out, stderr, ok) =
        figmn(&["train", "iris", "--delta", "1", "--beta", "0.001", "--kernel-mode", "fast"]);
    assert!(ok, "stderr: {stderr}");
    assert!(fast_out.contains("kernels=fast"), "{fast_out}");
    let auc = |s: &str| s.split("AUC ").nth(1).unwrap()[..5].to_string();
    assert_eq!(auc(&strict_out), auc(&fast_out));
    // The covariance baseline always runs strict kernels: the flag is
    // noted-and-ignored, and the output reports what actually ran.
    let (orig_out, stderr, ok) = figmn(&[
        "train", "iris", "--delta", "1", "--beta", "0.001", "--algo", "orig",
        "--kernel-mode", "fast",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(orig_out.contains("kernels=strict"), "{orig_out}");
    assert!(stderr.contains("strict kernels"), "{stderr}");
    // Unknown modes fail cleanly.
    let (_, stderr, ok) = figmn(&["train", "iris", "--kernel-mode", "warp"]);
    assert!(!ok);
    assert!(stderr.contains("kernel-mode"), "{stderr}");
}

#[test]
fn unknown_commands_fail_cleanly() {
    let (_, _, ok) = figmn(&["bogus"]);
    assert!(!ok);
    let (_, stderr, ok) = figmn(&["train", "no-such-dataset"]);
    assert!(!ok);
    assert!(stderr.contains("unknown dataset"));
}

#[test]
fn artifacts_lists_when_present() {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !manifest.exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let (stdout, stderr, ok) = figmn(&["artifacts"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("quickstart"));
    assert!(stdout.contains("compile check: OK"));
}
