#!/usr/bin/env python3
"""Diff fresh BENCH_*.json results against the committed baselines, and
enforce the benches' own correctness gates.

Usage: bench_diff.py BENCH_scaling_dim.json [BENCH_scaling_k.json ...]

For each file, the committed baseline is read from `git show HEAD:<file>`
(the checkout's version before the bench overwrote it). Metrics are
compared row by row with direction-aware semantics: higher-is-better
fields (`*_per_s`, `*speedup`) regress when they drop, lower-is-better
fields (`*_s_per_pt`, the scaling_dim per-point times) regress when they
rise.

Output is three sections:

- **GATE VIOLATIONS** — benches that embed a `gates` array (e.g.
  scaling_k's bitwise strict-vs-TopC identities) report each gate's
  `pass` verdict in the fresh document. Any `pass: false` is emitted as
  a `::error::` line and **fails this script with exit 1**, regardless
  of baseline state: gates are correctness, not perf.
- **REGRESSIONS (>10% worse)** — emitted as `::warning::` lines so
  GitHub surfaces them on the run page;
- **informational drift** — every other compared metric, including
  improvements, printed as plain `ok`/`drift` lines.

A baseline stamped `"provenance": "analytic-seed"` holds hand-derived
expectations committed before any machine recorded real numbers; its
metric comparisons are downgraded from warnings to drift lines (the
analytic numbers anchor the trajectory but are not measurements).
A baseline whose row-arrays are all empty produces a single "no
baseline yet" note instead. Refresh either kind with
`scripts/bench_smoke.sh` and commit the rewritten files.

Perf comparisons are report-only by design (quick-mode numbers on
shared CI runners are noisy); only gate violations set a nonzero exit.
"""

import json
import subprocess
import sys

# A metric more than 10% worse than baseline lands in the regression
# section; anything else is informational drift.
REGRESSION_THRESHOLD = 0.10

DEFAULT_FILES = [
    "BENCH_scaling_dim.json",
    "BENCH_layout_bandwidth.json",
    "BENCH_scaling_k.json",
    "BENCH_serving_concurrency.json",
    "BENCH_drift_adaptation.json",
]


def baseline_of(path):
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{path}"], capture_output=True, text=True, check=True
        ).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def gate_failures(path, fresh):
    """Failed entries of the fresh document's `gates` array, if any."""
    out = []
    for g in fresh.get("gates") or []:
        if isinstance(g, dict) and g.get("pass") is False:
            out.append(f"{path}: gate '{g.get('name', '?')}' failed")
    return out


def metric_keys(row):
    """(key, higher_is_better) pairs for the numeric metrics of a row."""
    out = []
    for k, v in row.items():
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            continue
        if k.endswith("_per_s") or k.endswith("speedup"):
            out.append((k, True))
        elif k.endswith("_s_per_pt"):
            out.append((k, False))
    return out


def row_key(row):
    """Identity of a row within its series (shape axes, not metrics)."""
    axes = ("d", "k", "c", "b", "threads", "scorers", "clients", "mode")
    return tuple(sorted((k, v) for k, v in row.items() if k in axes))


def series(doc):
    """All named row-arrays in a bench document (present even if empty)."""
    out = {}
    for key, val in (doc or {}).items():
        if key == "gates":
            continue
        if isinstance(val, list) and all(isinstance(r, dict) for r in val):
            out[key] = val
    return out


def compare(path, fresh, base_series):
    """Returns (regressions, drift, notes) line lists for one bench file."""
    regressions, drift, notes = [], [], []
    for name, fresh_rows in series(fresh).items():
        base_rows = {row_key(r): r for r in base_series.get(name, [])}
        if not base_rows:
            # A series the baseline predates (e.g. just added by a PR):
            # say so, or regressions in it go unnoticed until someone
            # remembers to refresh the baselines.
            if fresh_rows:
                notes.append(f"{path}:{name}: baseline has no rows; recording only")
            continue
        for row in fresh_rows:
            b = base_rows.get(row_key(row))
            if b is None:
                continue
            for k, higher_better in metric_keys(row):
                if k not in b or not b[k]:
                    continue
                ratio = row[k] / b[k]
                # Normalize so "goodness < 1" always means the fresh
                # number is worse than baseline.
                goodness = ratio if higher_better else 1.0 / ratio
                tag = f"{path}:{name} {dict(row_key(row))} {k}"
                line = f"{tag}: {b[k]:.3e} -> {row[k]:.3e} ({ratio:.2f}x)"
                if goodness < 1.0 - REGRESSION_THRESHOLD:
                    regressions.append(line)
                else:
                    drift.append(("ok" if goodness >= 1.0 else "drift") + " " + line)
    return regressions, drift, notes


def main(paths):
    all_gate_failures, all_regressions, all_drift, notes = [], [], [], []
    for path in paths:
        try:
            with open(path) as f:
                fresh = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            notes.append(f"{path}: cannot read fresh results ({e}); skipping")
            continue
        # Gates are checked on every fresh document, before (and
        # independent of) any baseline bookkeeping: a missing or stale
        # baseline must never mask a bitwise-identity violation.
        all_gate_failures.extend(gate_failures(path, fresh))
        base = baseline_of(path)
        if base is None:
            notes.append(f"{path}: no committed baseline (or unparsable); recording only")
            continue
        if base.get("quick") != fresh.get("quick"):
            notes.append(f"{path}: baseline/fresh quick-mode mismatch; recording only")
            continue
        base_series = series(base)
        if base_series and all(not rows for rows in base_series.values()):
            notes.append(
                f"{path}: no baseline yet (seed stub with empty rows) — run "
                "scripts/bench_smoke.sh on a quiet machine and commit the "
                "rewritten file to establish the trajectory"
            )
            continue
        regressions, drift, series_notes = compare(path, fresh, base_series)
        if regressions and base.get("provenance") == "analytic-seed":
            notes.append(
                f"{path}: analytic-seed baseline — {len(regressions)} would-be "
                "regression(s) downgraded to drift (commit measured numbers to arm "
                "the warnings)"
            )
            drift = drift + ["drift(analytic) " + r for r in regressions]
            regressions = []
        all_regressions.extend(regressions)
        all_drift.extend(drift)
        notes.extend(series_notes)

    for note in notes:
        print(note)
    if all_drift:
        print(f"\n-- informational drift ({len(all_drift)} metric(s) compared) --")
        for line in all_drift:
            print(line)
    print(f"\n-- REGRESSIONS (> {REGRESSION_THRESHOLD:.0%} worse than baseline) --")
    if all_regressions:
        for line in all_regressions:
            print(f"::warning::bench regression {line}")
    else:
        print("none")
    print("\n-- GATE VIOLATIONS (bitwise/correctness gates) --")
    if all_gate_failures:
        for line in all_gate_failures:
            print(f"::error::bench gate violation {line}")
    else:
        print("none")
    print(
        f"\nbench_diff: {len(all_regressions)} regression(s) beyond "
        f"{REGRESSION_THRESHOLD:.0%} (report-only), "
        f"{len(all_gate_failures)} gate violation(s) (fatal)"
    )
    return 1 if all_gate_failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or DEFAULT_FILES))
