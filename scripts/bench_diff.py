#!/usr/bin/env python3
"""Diff-report fresh BENCH_*.json results against the committed baselines.

Usage: bench_diff.py BENCH_scaling_dim.json [BENCH_layout_bandwidth.json ...]

For each file, the committed baseline is read from `git show HEAD:<file>`
(the checkout's version before the bench overwrote it). Metrics are
compared row by row with direction-aware semantics: higher-is-better
fields (`*_per_s`, `speedup`/`fast_speedup`) regress when they drop,
lower-is-better fields (`*_s_per_pt`, the scaling_dim per-point times)
regress when they rise; either direction beyond THRESHOLD is reported.

Report-only by design: quick-mode numbers on shared CI runners are
noisy, so this prints a table (and ::warning:: lines GitHub renders on
the run page) but always exits 0. Refresh the baselines with
`scripts/bench_smoke.sh` and commit the rewritten files.
"""

import json
import subprocess
import sys

THRESHOLD = 0.30  # flag drops of more than 30%


def baseline_of(path):
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{path}"], capture_output=True, text=True, check=True
        ).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def metric_keys(row):
    """(key, higher_is_better) pairs for the numeric metrics of a row."""
    out = []
    for k, v in row.items():
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            continue
        if k.endswith("_per_s") or k in ("speedup", "fast_speedup"):
            out.append((k, True))
        elif k.endswith("_s_per_pt"):
            out.append((k, False))
    return out


def row_key(row):
    return tuple(sorted((k, v) for k, v in row.items() if k in ("d", "k", "threads", "mode")))


def series(doc):
    """All named row-arrays in a bench document."""
    out = {}
    for key, val in (doc or {}).items():
        if isinstance(val, list) and val and isinstance(val[0], dict):
            out[key] = val
    return out


def main(paths):
    regressions = 0
    for path in paths:
        try:
            with open(path) as f:
                fresh = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: cannot read fresh results ({e}); skipping")
            continue
        base = baseline_of(path)
        if base is None:
            print(f"{path}: no committed baseline (or unparsable); recording only")
            continue
        if base.get("quick") != fresh.get("quick"):
            print(f"{path}: baseline/fresh quick-mode mismatch; recording only")
            continue
        base_series = series(base)
        for name, fresh_rows in series(fresh).items():
            base_rows = {row_key(r): r for r in base_series.get(name, [])}
            if not base_rows:
                print(f"{path}:{name}: baseline has no rows; recording only")
                continue
            for row in fresh_rows:
                b = base_rows.get(row_key(row))
                if b is None:
                    continue
                for k, higher_better in metric_keys(row):
                    if k not in b or not b[k]:
                        continue
                    ratio = row[k] / b[k]
                    # Normalize so "goodness < 1 - THRESHOLD" always
                    # means the fresh number is worse than baseline.
                    goodness = ratio if higher_better else 1.0 / ratio
                    tag = f"{path}:{name} {dict(row_key(row))} {k}"
                    if goodness < 1.0 - THRESHOLD:
                        regressions += 1
                        print(
                            f"::warning::bench regression {tag}: "
                            f"{b[k]:.3e} -> {row[k]:.3e} ({ratio:.2f}x)"
                        )
                    else:
                        print(f"ok {tag}: {b[k]:.3e} -> {row[k]:.3e} ({ratio:.2f}x)")
    print(f"bench_diff: {regressions} regression(s) beyond {THRESHOLD:.0%} (report-only)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["BENCH_scaling_dim.json", "BENCH_layout_bandwidth.json"]))
