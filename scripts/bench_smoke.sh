#!/usr/bin/env bash
# Quick-mode bench smoke: writes BENCH_scaling_dim.json,
# BENCH_layout_bandwidth.json, BENCH_scaling_k.json,
# BENCH_serving_concurrency.json and BENCH_drift_adaptation.json at the
# repo root — the same files CI's bench-smoke job produces and diffs
# against the committed baselines.
#
#   ./scripts/bench_smoke.sh            # quick mode (default)
#   FIGMN_BENCH_QUICK=0 ./scripts/bench_smoke.sh   # full mode (slow;
#                                       # runs the perf assertions)
#
# To refresh the committed baselines, run this and commit the
# BENCH_*.json files it rewrites. bench_diff.py exits nonzero when any
# bench-embedded correctness gate reports `pass: false` (perf drift
# stays report-only), and set -e propagates that here.
set -euo pipefail
cd "$(dirname "$0")/.."

export FIGMN_BENCH_QUICK="${FIGMN_BENCH_QUICK:-1}"

cargo bench --bench scaling_dim
cargo bench --bench layout_bandwidth
cargo bench --bench scaling_k
cargo bench --bench serving_concurrency
cargo bench --bench drift_adaptation

if command -v python3 >/dev/null 2>&1; then
  python3 scripts/bench_diff.py \
    BENCH_scaling_dim.json BENCH_layout_bandwidth.json BENCH_scaling_k.json \
    BENCH_serving_concurrency.json BENCH_drift_adaptation.json
fi
